//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The solvebak build environment has no network access, so instead of a
//! registry dependency this vendored shim provides exactly the surface the
//! runtime layer uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]
//! macros, and the [`Context`] extension trait. Errors are flattened to a
//! message string (no backtraces, no source chains) — enough for the
//! "report upward and degrade" error handling the crate does.

use std::fmt;

/// A flattened error message.
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Like real anyhow: any std error converts; Error itself deliberately does
// NOT implement std::error::Error so this blanket impl stays coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring anyhow's `Context` trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Format an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_formats() {
        let e: Error = anyhow!("bad {} at {}", "thing", 3);
        assert_eq!(e.to_string(), "bad thing at 3");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "nope");
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let o: Option<u8> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn from_std_error() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
