//! Coordinator integration: concurrency, batching, backpressure, metrics —
//! the service-level behaviour under load.

use std::sync::Arc;

use solvebak::coordinator::{
    Backend, Coordinator, CoordinatorConfig, SolveRequest,
};
use solvebak::coordinator::batch::BatchPolicy;
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn planted_rhs(x: &Mat, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let a: Vec<f32> = (0..x.cols()).map(|_| rng.normal_f32()).collect();
    (x.matvec(&a), a)
}

#[test]
fn many_concurrent_clients_all_served_correctly() {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers: 4,
        ..CoordinatorConfig::default()
    }));
    let mut rng = Rng::seed(900);
    let x = Arc::new(Mat::randn(&mut rng, 400, 24));

    let handles: Vec<_> = (0..16u64)
        .map(|i| {
            let coord = coord.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let (y, a_true) = planted_rhs(&x, 1000 + i);
                let mut req = SolveRequest::new(i, x.clone(), y);
                req.backend = Backend::Bak;
                req.opts = SolveOptions::accurate();
                let out = coord.solve_blocking(req);
                let rep = out.report.expect("solve ok");
                assert_eq!(out.id, i);
                assert!(rel_l2(&rep.a, &a_true) < 1e-3, "client {i}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics();
    assert_eq!(
        m.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        16
    );
    assert_eq!(m.requests_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn batching_coalesces_under_burst() {
    // One worker + a burst of same-matrix requests: the scheduler's
    // drain-window must coalesce at least some of them.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        batch: BatchPolicy { max_batch: 64 },
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(901);
    let x = Arc::new(Mat::randn(&mut rng, 600, 40));
    let rxs: Vec<_> = (0..24u64)
        .map(|i| {
            let (y, _) = planted_rhs(&x, 2000 + i);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = Backend::Qr; // QR batches share one factorization
            coord.submit(req).unwrap()
        })
        .collect();
    let mut max_batch = 0;
    for rx in rxs {
        let out = rx.recv().unwrap();
        assert!(out.report.is_ok());
        max_batch = max_batch.max(out.batch_size);
    }
    assert!(
        max_batch >= 2,
        "burst of 24 same-matrix requests never batched (max={max_batch})"
    );
    coord.shutdown();
}

#[test]
fn try_submit_backpressure_rejects_when_full() {
    // Tiny queue + slow jobs: try_submit must eventually reject.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: 1,
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(902);
    let x = Arc::new(Mat::randn(&mut rng, 2000, 200));
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..50u64 {
        let (y, _) = planted_rhs(&x, 3000 + i);
        let mut req = SolveRequest::new(i, x.clone(), y);
        req.backend = Backend::Bak;
        req.opts.max_sweeps = 50;
        match coord.try_submit(req) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    assert!(rejected > 0, "queue_capacity=1 must reject under a 50-burst");
    assert_eq!(
        coord.metrics().queue_rejections.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    coord.shutdown();
}

#[test]
fn mixed_backends_in_one_burst() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 3,
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(903);
    let x = Arc::new(Mat::randn(&mut rng, 300, 20));
    let backends = [Backend::Bak, Backend::Bakp, Backend::Qr, Backend::Auto];
    let rxs: Vec<_> = (0..12u64)
        .map(|i| {
            let (y, a) = planted_rhs(&x, 4000 + i);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = backends[i as usize % backends.len()];
            req.opts = SolveOptions::accurate();
            (a, coord.submit(req).unwrap())
        })
        .collect();
    for (a_true, rx) in rxs {
        let out = rx.recv().unwrap();
        let rep = out.report.expect("solve ok");
        assert!(rel_l2(&rep.a, &a_true) < 1e-2);
    }
    coord.shutdown();
}

#[test]
fn wide_system_requests_served() {
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::seed(904);
    let x = Arc::new(Mat::randn(&mut rng, 30, 200)); // wide
    let y: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
    let mut req = SolveRequest::new(1, x.clone(), y.clone());
    req.backend = Backend::Qr; // min-norm path
    let out = coord.solve_blocking(req);
    let rep = out.report.expect("wide qr ok");
    // Wide systems interpolate.
    let e = solvebak::linalg::residual(&x, &y, &rep.a);
    assert!(solvebak::linalg::blas1::nrm2(&e) < 1e-3);
    coord.shutdown();
}

#[test]
fn queue_wait_metric_recorded() {
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::seed(905);
    let x = Arc::new(Mat::randn(&mut rng, 100, 10));
    let (y, _) = planted_rhs(&x, 5000);
    let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
    assert!(coord.metrics().queue_wait.count() >= 1);
    let j = coord.metrics().to_json();
    assert!(j.get("jobs_run").unwrap().as_f64().unwrap() >= 1.0);
    coord.shutdown();
}

#[test]
fn traced_request_end_to_end_trajectory_and_span_accounting() {
    // The PR-7 acceptance path: a traced solve returns (a) a per-sweep
    // residual trajectory that never increases — the paper's "accuracy is
    // straightforwardly controlled" claim made observable — and (b) a span
    // timeline whose top-level stage durations are bounded by the
    // request's total wall latency.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(907);
    let x = Arc::new(Mat::randn(&mut rng, 500, 30));
    let (y, a_true) = planted_rhs(&x, 7000);
    let req = SolveRequest::builder(1, x, y)
        .backend(Backend::Bak)
        .opts(SolveOptions::accurate())
        .trace(true)
        .build();

    let t0 = std::time::Instant::now();
    let out = coord.solve_blocking(req);
    let total_ns = t0.elapsed().as_nanos() as u64;

    let rep = out.report.expect("traced solve ok");
    assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    let tel = out.telemetry.expect("telemetry on traced outcome");

    // (a) Monotonically non-increasing residual trajectory.
    assert!(tel.trajectory.len() >= 2, "want a real curve, got {:?}", tel.trajectory);
    for w in tel.trajectory.windows(2) {
        assert!(
            w[1].residual_norm <= w[0].residual_norm * (1.0 + 1e-9),
            "residual increased: {} -> {} at sweep {}",
            w[0].residual_norm,
            w[1].residual_norm,
            w[1].sweep
        );
    }
    // Probe timestamps move forward with the sweeps.
    for w in tel.trajectory.windows(2) {
        assert!(w[1].elapsed_ns >= w[0].elapsed_ns);
        assert!(w[1].sweep > w[0].sweep);
    }

    // (b) Span accounting: every span closed, and the top-level stages
    // (parent == None) sum to no more than the observed wall latency.
    let names: Vec<&str> = tel.spans.iter().map(|s| s.name).collect();
    for stage in ["queue_wait", "route", "solve", "merge"] {
        assert!(names.contains(&stage), "missing {stage} in {names:?}");
    }
    let mut top_level_ns = 0u64;
    for s in &tel.spans {
        assert!(s.end_ns >= s.start_ns, "span {} not closed", s.name);
        if s.parent.is_none() {
            top_level_ns += s.duration_ns();
        }
    }
    assert!(
        top_level_ns <= total_ns,
        "stage durations {top_level_ns}ns exceed total latency {total_ns}ns"
    );

    // The trace is also retained service-side for the `traces` command.
    let recent = coord.traces().recent(4);
    assert!(recent.iter().any(|t| t.trace_id == tel.trace_id));
    coord.shutdown();
}

#[test]
fn traced_and_untraced_requests_coexist_in_a_burst() {
    // Traced requests must become singleton jobs while the untraced rest
    // of the burst still batches — and answers stay correct for all.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        batch: BatchPolicy { max_batch: 64 },
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(908);
    let x = Arc::new(Mat::randn(&mut rng, 400, 24));
    let rxs: Vec<_> = (0..12u64)
        .map(|i| {
            let (y, a) = planted_rhs(&x, 8000 + i);
            let req = SolveRequest::builder(i, x.clone(), y)
                .backend(Backend::Bak)
                .opts(SolveOptions::accurate())
                .trace(i % 3 == 0)
                .build();
            (i, a, coord.submit(req).unwrap())
        })
        .collect();
    for (i, a_true, rx) in rxs {
        let out = rx.recv().unwrap();
        let rep = out.report.expect("solve ok");
        assert!(rel_l2(&rep.a, &a_true) < 1e-3, "request {i}");
        if i % 3 == 0 {
            let tel = out.telemetry.expect("traced member has telemetry");
            assert_eq!(out.batch_size, 1, "traced request was coalesced");
            assert!(!tel.trajectory.is_empty());
        } else {
            assert!(out.telemetry.is_none(), "untraced member grew telemetry");
        }
    }
    coord.shutdown();
}

#[test]
fn drop_without_shutdown_is_clean() {
    let mut rng = Rng::seed(906);
    let x = Arc::new(Mat::randn(&mut rng, 50, 5));
    let (y, _) = planted_rhs(&x, 6000);
    {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
        // coord dropped here; Drop impl joins all threads.
    }
}
