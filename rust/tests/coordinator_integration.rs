//! Coordinator integration: concurrency, batching, backpressure, metrics —
//! the service-level behaviour under load.

use std::sync::Arc;

use solvebak::coordinator::{
    Backend, Coordinator, CoordinatorConfig, SolveRequest,
};
use solvebak::coordinator::batch::BatchPolicy;
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn planted_rhs(x: &Mat, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let a: Vec<f32> = (0..x.cols()).map(|_| rng.normal_f32()).collect();
    (x.matvec(&a), a)
}

#[test]
fn many_concurrent_clients_all_served_correctly() {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers: 4,
        ..CoordinatorConfig::default()
    }));
    let mut rng = Rng::seed(900);
    let x = Arc::new(Mat::randn(&mut rng, 400, 24));

    let handles: Vec<_> = (0..16u64)
        .map(|i| {
            let coord = coord.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let (y, a_true) = planted_rhs(&x, 1000 + i);
                let mut req = SolveRequest::new(i, x.clone(), y);
                req.backend = Backend::Bak;
                req.opts = SolveOptions::accurate();
                let out = coord.solve_blocking(req);
                let rep = out.report.expect("solve ok");
                assert_eq!(out.id, i);
                assert!(rel_l2(&rep.a, &a_true) < 1e-3, "client {i}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics();
    assert_eq!(
        m.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        16
    );
    assert_eq!(m.requests_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn batching_coalesces_under_burst() {
    // One worker + a burst of same-matrix requests: the scheduler's
    // drain-window must coalesce at least some of them.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        batch: BatchPolicy { max_batch: 64 },
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(901);
    let x = Arc::new(Mat::randn(&mut rng, 600, 40));
    let rxs: Vec<_> = (0..24u64)
        .map(|i| {
            let (y, _) = planted_rhs(&x, 2000 + i);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = Backend::Qr; // QR batches share one factorization
            coord.submit(req).unwrap()
        })
        .collect();
    let mut max_batch = 0;
    for rx in rxs {
        let out = rx.recv().unwrap();
        assert!(out.report.is_ok());
        max_batch = max_batch.max(out.batch_size);
    }
    assert!(
        max_batch >= 2,
        "burst of 24 same-matrix requests never batched (max={max_batch})"
    );
    coord.shutdown();
}

#[test]
fn try_submit_backpressure_rejects_when_full() {
    // Tiny queue + slow jobs: try_submit must eventually reject.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: 1,
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(902);
    let x = Arc::new(Mat::randn(&mut rng, 2000, 200));
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..50u64 {
        let (y, _) = planted_rhs(&x, 3000 + i);
        let mut req = SolveRequest::new(i, x.clone(), y);
        req.backend = Backend::Bak;
        req.opts.max_sweeps = 50;
        match coord.try_submit(req) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    assert!(rejected > 0, "queue_capacity=1 must reject under a 50-burst");
    assert_eq!(
        coord.metrics().queue_rejections.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    coord.shutdown();
}

#[test]
fn mixed_backends_in_one_burst() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 3,
        ..CoordinatorConfig::default()
    });
    let mut rng = Rng::seed(903);
    let x = Arc::new(Mat::randn(&mut rng, 300, 20));
    let backends = [Backend::Bak, Backend::Bakp, Backend::Qr, Backend::Auto];
    let rxs: Vec<_> = (0..12u64)
        .map(|i| {
            let (y, a) = planted_rhs(&x, 4000 + i);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = backends[i as usize % backends.len()];
            req.opts = SolveOptions::accurate();
            (a, coord.submit(req).unwrap())
        })
        .collect();
    for (a_true, rx) in rxs {
        let out = rx.recv().unwrap();
        let rep = out.report.expect("solve ok");
        assert!(rel_l2(&rep.a, &a_true) < 1e-2);
    }
    coord.shutdown();
}

#[test]
fn wide_system_requests_served() {
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::seed(904);
    let x = Arc::new(Mat::randn(&mut rng, 30, 200)); // wide
    let y: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
    let mut req = SolveRequest::new(1, x.clone(), y.clone());
    req.backend = Backend::Qr; // min-norm path
    let out = coord.solve_blocking(req);
    let rep = out.report.expect("wide qr ok");
    // Wide systems interpolate.
    let e = solvebak::linalg::residual(&x, &y, &rep.a);
    assert!(solvebak::linalg::blas1::nrm2(&e) < 1e-3);
    coord.shutdown();
}

#[test]
fn queue_wait_metric_recorded() {
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::seed(905);
    let x = Arc::new(Mat::randn(&mut rng, 100, 10));
    let (y, _) = planted_rhs(&x, 5000);
    let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
    assert!(coord.metrics().queue_wait.count() >= 1);
    let j = coord.metrics().to_json();
    assert!(j.get("jobs_run").unwrap().as_f64().unwrap() >= 1.0);
    coord.shutdown();
}

#[test]
fn drop_without_shutdown_is_clean() {
    let mut rng = Rng::seed(906);
    let x = Arc::new(Mat::randn(&mut rng, 50, 5));
    let (y, _) = planted_rhs(&x, 6000);
    {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
        // coord dropped here; Drop impl joins all threads.
    }
}
