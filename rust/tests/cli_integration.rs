//! CLI integration: drive the `solvebak` subcommands through the library
//! entry point (no subprocess spawning — same code path as main()).

use solvebak::cli::run;

fn sv(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

#[test]
fn help_exits_zero() {
    assert_eq!(run(sv(&["help"])), 0);
    assert_eq!(run(sv(&[])), 0); // no args -> help
}

#[test]
fn solve_bak_small() {
    assert_eq!(
        run(sv(&["solve", "--obs", "400", "--vars", "20", "--backend", "bak", "--seed", "7"])),
        0
    );
}

#[test]
fn solve_bakp_threaded() {
    assert_eq!(
        run(sv(&[
            "solve", "--obs", "500", "--vars", "40", "--backend", "bakp",
            "--thr", "8", "--threads", "2",
        ])),
        0
    );
}

#[test]
fn solve_qr_square() {
    assert_eq!(
        run(sv(&["solve", "--obs", "60", "--vars", "60", "--backend", "qr"])),
        0
    );
}

#[test]
fn solve_scientific_notation_dims() {
    assert_eq!(
        run(sv(&["solve", "--obs", "1e3", "--vars", "50", "--backend", "bak"])),
        0
    );
}

#[test]
fn features_recovers() {
    assert_eq!(
        run(sv(&["features", "--obs", "500", "--vars", "30", "--max-feat", "4"])),
        0
    );
}

#[test]
fn serve_small_load() {
    assert_eq!(
        run(sv(&[
            "serve", "--requests", "8", "--workers", "2", "--obs", "300",
            "--vars", "20", "--backend", "bak",
        ])),
        0
    );
}

#[test]
fn info_runs_with_or_without_artifacts() {
    assert_eq!(run(sv(&["info"])), 0);
    assert_eq!(run(sv(&["info", "--artifacts", "/nonexistent"])), 0);
}

#[test]
fn unknown_command_exit_code() {
    assert_eq!(run(sv(&["bogus"])), 2);
}

#[test]
fn bad_option_value_exit_code() {
    assert_eq!(run(sv(&["solve", "--obs", "NaNny"])), 2);
}
