//! Integration contract for the parallel execution layer (ISSUE 3
//! acceptance): the worker pool isolates panics and drains on shutdown,
//! and the block-parallel solvers are deterministic per (seed, threads)
//! with residuals within tolerance of their serial counterparts for
//! threads in {1, 2, 8}.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use solvebak::api::{solver_for, Problem, SolverKind};
use solvebak::bench::workload::{SparseWorkload, WorkloadSpec};
use solvebak::linalg::Mat;
use solvebak::parallel::{self, Executor};
use solvebak::solver::{solve_bak, solve_kaczmarz, SolveOptions};
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a);
    (x, y, a)
}

#[test]
fn executor_runs_jobs_across_workers() {
    let sum = Arc::new(AtomicU64::new(0));
    let s2 = sum.clone();
    let pool = Executor::start("itest", 4, 64, move |_w, v: u64| {
        s2.fetch_add(v, Ordering::Relaxed);
    });
    for v in 1..=100u64 {
        pool.submit(v).unwrap();
    }
    let stats = pool.stats();
    pool.shutdown();
    assert_eq!(sum.load(Ordering::Relaxed), 5050);
    assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 100);
    assert_eq!(stats.worker_jobs().iter().sum::<u64>(), 100);
}

#[test]
fn executor_panic_isolation_keeps_serving() {
    let ok = Arc::new(AtomicU64::new(0));
    let ok2 = ok.clone();
    let pool = Executor::start("itest-panic", 2, 16, move |_w, v: i64| {
        if v % 5 == 0 {
            panic!("job {v} exploded");
        }
        ok2.fetch_add(1, Ordering::Relaxed);
    });
    for v in 1..=20i64 {
        pool.submit(v).unwrap();
    }
    let stats = pool.stats();
    pool.shutdown();
    // 4 of 20 jobs panic (5, 10, 15, 20); the other 16 all complete.
    assert_eq!(ok.load(Ordering::Relaxed), 16);
    assert_eq!(stats.jobs_panicked.load(Ordering::Relaxed), 4);
    assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 16);
    assert_eq!(stats.jobs_inflight.load(Ordering::Relaxed), 0);
}

#[test]
fn executor_shutdown_with_pending_jobs_drains_cleanly() {
    let done = Arc::new(AtomicU64::new(0));
    let d2 = done.clone();
    // One slow worker, a queue full of pending jobs, immediate shutdown:
    // every queued job must still execute before the workers exit.
    let pool = Executor::start("itest-drain", 1, 64, move |_w, _v: u32| {
        std::thread::sleep(Duration::from_millis(3));
        d2.fetch_add(1, Ordering::Relaxed);
    });
    for v in 0..20u32 {
        pool.submit(v).unwrap();
    }
    pool.shutdown();
    assert_eq!(done.load(Ordering::Relaxed), 20, "pending jobs drained");
}

#[test]
fn bak_par_deterministic_and_within_tolerance_of_serial() {
    let (x, y, _) = planted(7001, 800, 64);
    let opts_serial = SolveOptions::accurate();
    let serial = solve_bak(&x, &y, &opts_serial);
    for threads in [1usize, 2, 8] {
        let mut o = SolveOptions::accurate();
        o.threads = threads;
        let r1 = parallel::solve_bak_par(&x, &y, &o);
        let r2 = parallel::solve_bak_par(&x, &y, &o);
        assert_eq!(r1.a, r2.a, "threads={threads}: repeat runs identical");
        // Acceptance: residual within tolerance of the serial counterpart.
        assert!(
            r1.rel_residual() < 1e-4,
            "threads={threads} rel={}",
            r1.rel_residual()
        );
        assert!(
            rel_l2(&r1.a, &serial.a) < 1e-2,
            "threads={threads} drift={}",
            rel_l2(&r1.a, &serial.a)
        );
    }
}

#[test]
fn kaczmarz_par_deterministic_and_within_tolerance_of_serial() {
    let (x, y, _) = planted(7002, 320, 24);
    let mut opts_serial = SolveOptions::default();
    opts_serial.max_sweeps = 2000;
    opts_serial.tol = 1e-4;
    let serial = solve_kaczmarz(&x, &y, &opts_serial);
    for threads in [1usize, 2, 8] {
        let mut o = opts_serial.clone();
        o.threads = threads;
        let r1 = parallel::solve_kaczmarz_par(&x, &y, &o);
        let r2 = parallel::solve_kaczmarz_par(&x, &y, &o);
        assert_eq!(r1.a, r2.a, "threads={threads}: repeat runs identical");
        assert!(
            r1.rel_residual() < 1e-3,
            "threads={threads} rel={}",
            r1.rel_residual()
        );
        assert!(
            rel_l2(&r1.a, &serial.a) < 0.05,
            "threads={threads} drift={}",
            rel_l2(&r1.a, &serial.a)
        );
    }
}

#[test]
fn sparse_parallel_variants_through_the_registry() {
    let w = SparseWorkload::uniform(WorkloadSpec::new(640, 32, 7003), 0.1);
    let opts = SolveOptions::builder()
        .max_sweeps(2000)
        .tol(1e-4)
        .threads(2)
        .build();
    for kind in [SolverKind::BakPar, SolverKind::KaczmarzPar] {
        let solver = solver_for(kind).expect("registered");
        assert!(solver.capabilities().supports_parallel, "{kind}");
        assert!(solver.capabilities().supports_sparse, "{kind}");
        let p = Problem::new_sparse(&w.x, &w.y).expect("valid");
        let rep = solver.solve(&p, &opts).expect("sparse parallel solve");
        assert!(
            rep.rel_residual() < 1e-3,
            "{kind}: rel={}",
            rep.rel_residual()
        );
    }
}

#[test]
fn multi_rhs_parallel_matches_individual_serial_solves() {
    let (x, _, _) = planted(7004, 400, 32);
    let mut rng = Rng::seed(7005);
    let ys: Vec<Vec<f32>> = (0..6)
        .map(|_| {
            let a: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            x.matvec(&a)
        })
        .collect();
    let mut o = SolveOptions::accurate();
    o.threads = 3;
    let reps = parallel::solve_bak_multi_par(&x, &ys, &o);
    assert_eq!(reps.len(), 6);
    let mut o_serial = SolveOptions::accurate();
    o_serial.threads = 1;
    for (rep, y) in reps.iter().zip(&ys) {
        let single = solve_bak(&x, y, &o_serial);
        assert!(
            rel_l2(&rep.a, &single.a) < 1e-4,
            "multi-par member drifted: {}",
            rel_l2(&rep.a, &single.a)
        );
    }
}
