//! Durability & self-healing integration: checkpoint/resume, chunk
//! integrity, and watchdog escalation exercised end-to-end through the
//! crate's public surface — the same paths CI's `recovery-smoke` job
//! drives over TCP.

use std::path::PathBuf;
use std::sync::Arc;

use solvebak::api::{solver_for, Problem, SolverError, SolverKind};
use solvebak::coordinator::{Coordinator, CoordinatorConfig, SolveRequest};
use solvebak::linalg::Mat;
use solvebak::obs::ProbeHandle;
use solvebak::robust::watchdog::WatchdogConfig;
use solvebak::robust::{Checkpoint, CheckpointProbe};
use solvebak::solver::SolveOptions;
use solvebak::stream::{temp_chunk_path, StreamedMatrix, MAGIC};
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);
    (x, a_true, y)
}

fn temp_dir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("pallas_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).expect("temp journal dir");
    p
}

#[test]
fn checkpoint_roundtrips_and_rejects_any_flipped_byte() {
    let ck = Checkpoint {
        job_id: "recovery-it".into(),
        solver: "bak".into(),
        sweeps: 17,
        seed: 0x5eed,
        a: vec![0.25, -1.5, 3.0],
        e: vec![0.5, 0.0, -0.125, 2.0],
    };
    let path = temp_dir("roundtrip").join("job.ckpt");
    ck.save_atomic(&path).expect("atomic save");
    assert_eq!(Checkpoint::load(&path).expect("load back"), ck);

    // Every single-byte flip anywhere in the file must be rejected by the
    // CRC trailer before any field is trusted.
    let good = std::fs::read(&path).unwrap();
    for idx in 0..good.len() {
        let mut bad = good.clone();
        bad[idx] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "flip at byte {idx} accepted");
    }
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn probe_checkpoint_resumes_bit_identically() {
    // An uninterrupted 8-sweep BAK run is the reference; a 3-sweep run
    // checkpointed through the public probe hook, resumed via
    // with_warm_state for the remaining 5, must match it bit-for-bit.
    let (x, _, y) = planted(4111, 160, 14);
    let solver = solver_for(SolverKind::Bak).expect("registered");
    let full_opts = SolveOptions::builder()
        .max_sweeps(8)
        .tol(0.0)
        .check_every(1)
        .build();
    let full = solver
        .solve(&Problem::new(&x, &y).unwrap(), &full_opts)
        .expect("reference solve");

    let path = temp_dir("resume").join("bitident.ckpt");
    let probe = CheckpointProbe::new(&path, "bitident", "bak", full_opts.seed, 1);
    let part_opts = SolveOptions::builder()
        .max_sweeps(3)
        .tol(0.0)
        .check_every(1)
        .probe(ProbeHandle::new(probe.clone()))
        .build();
    let part = solver
        .solve(&Problem::new(&x, &y).unwrap(), &part_opts)
        .expect("partial solve");
    assert_eq!(part.sweeps, 3);
    assert!(probe.written() >= 1, "probe never persisted");
    assert!(probe.last_error().is_none(), "{:?}", probe.last_error());

    let ck = Checkpoint::load(&path).expect("checkpoint on disk");
    assert_eq!(ck.sweeps, 3);
    assert_eq!(ck.a, part.a, "checkpoint captured the 3-sweep iterate");

    let warm = Problem::new(&x, &y)
        .unwrap()
        .with_warm_state(&ck.a, &ck.e)
        .expect("warm state accepted");
    let rest_opts = SolveOptions::builder()
        .max_sweeps(5)
        .tol(0.0)
        .check_every(1)
        .build();
    let resumed = solver.solve(&warm, &rest_opts).expect("resumed solve");
    assert_eq!(
        resumed.a, full.a,
        "3 + 5 checkpoint-resumed sweeps must equal 8 uninterrupted ones bitwise"
    );
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn coordinator_journal_survives_job_id_resubmission() {
    // End-to-end through the coordinator: first submission under a job_id
    // runs 3 sweeps and leaves a journal entry (deadline-free but
    // sweep-capped solves keep nothing — so emulate the interrupted run
    // by planting the checkpoint a killed process would have left), then
    // the re-submission warm-starts and lands exactly where an
    // uninterrupted run would.
    let dir = temp_dir("journal");
    let (x, _, y) = planted(4222, 140, 10);

    let reference = {
        let solver = solver_for(SolverKind::Bak).unwrap();
        let opts = SolveOptions::builder().max_sweeps(7).tol(0.0).check_every(1).build();
        solver.solve(&Problem::new(&x, &y).unwrap(), &opts).unwrap()
    };
    let partial = {
        let solver = solver_for(SolverKind::Bak).unwrap();
        let opts = SolveOptions::builder().max_sweeps(3).tol(0.0).check_every(1).build();
        solver.solve(&Problem::new(&x, &y).unwrap(), &opts).unwrap()
    };

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        journal_dir: Some(dir.clone()),
        checkpoint_every: 1,
        ..CoordinatorConfig::default()
    });

    let opts = SolveOptions::builder().max_sweeps(3).tol(0.0).check_every(1).build();
    let first = coord.solve_blocking(
        SolveRequest::builder(1, Arc::new(x.clone()), y.clone())
            .backend(SolverKind::Bak)
            .opts(opts.clone())
            .job_id("journal-key")
            .build(),
    );
    let rep1 = first.report.expect("first durable solve");
    assert_eq!(rep1.a, partial.a, "first pass is the plain 3-sweep solve");
    assert!(!first.resumed);

    // The sweep-capped job completed, so its journal entry was cleared;
    // recreate the "killed mid-solve" state from the partial report.
    let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert!(entries.is_empty(), "completed job must clear its journal entry");
    let ck = Checkpoint {
        job_id: "journal-key".into(),
        solver: "bak".into(),
        sweeps: partial.sweeps as u64,
        seed: opts.seed,
        a: partial.a.clone(),
        e: partial.e.clone(),
    };
    // Journal file names are `<sanitised-id>-<crc32-hex>.ckpt`; plant the
    // checkpoint where the coordinator will look for this job_id.
    let planted_path = dir.join(format!(
        "journal-key-{:08x}.ckpt",
        solvebak::util::crc32::crc32(b"journal-key")
    ));
    ck.save_atomic(&planted_path).expect("plant checkpoint");

    let second = coord.solve_blocking(
        SolveRequest::builder(2, Arc::new(x.clone()), y.clone())
            .backend(SolverKind::Bak)
            .opts(SolveOptions::builder().max_sweeps(4).tol(0.0).check_every(1).build())
            .job_id("journal-key")
            .build(),
    );
    let rep2 = second.report.expect("resumed solve");
    assert!(second.resumed, "planted journal entry must trigger a resume");
    assert_eq!(
        rep2.a, reference.a,
        "3 checkpointed + 4 resumed sweeps must equal 7 uninterrupted ones bitwise"
    );
    let m = coord.metrics();
    assert!(m.resumes.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn escalation_walks_the_ladder_in_order() {
    // A hair-trigger stagnation watchdog declares breakdown on BAK at the
    // second residual check; CGLS forwards the probe (and trips it too);
    // QR — probe-blind, direct — answers. The reply must name QR and the
    // escalation counter must record both hops, proving the BAK → CGLS →
    // QR order was walked, not skipped.
    let (x, a_true, y) = planted(4333, 120, 12);
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        watchdog: WatchdogConfig {
            stagnation_patience: 1,
            stagnation_epsilon: 1.0,
            ..WatchdogConfig::default()
        },
        ..CoordinatorConfig::default()
    });
    let out = coord.solve_blocking(
        SolveRequest::builder(9, Arc::new(x), y)
            .backend(SolverKind::Bak)
            .opts(SolveOptions::builder().max_sweeps(50).tol(0.0).check_every(1).build())
            .escalate(true)
            .build(),
    );
    let rep = out.report.expect("escalated solve must answer");
    assert_eq!(out.escalated_to, Some(SolverKind::Qr), "ladder ends at QR");
    assert_eq!(out.backend, SolverKind::Qr);
    assert!(rep.a.iter().all(|v| v.is_finite()));
    assert!(rel_l2(&rep.a, &a_true) < 1e-3, "QR answer must be accurate");
    let m = coord.metrics();
    assert_eq!(
        m.escalations.load(std::sync::atomic::Ordering::Relaxed),
        2,
        "BAK→CGLS and CGLS→QR are two recorded hops"
    );
    coord.shutdown();
}

#[test]
fn breakdown_without_escalation_is_typed() {
    let (x, _, y) = planted(4444, 120, 12);
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        watchdog: WatchdogConfig {
            stagnation_patience: 1,
            stagnation_epsilon: 1.0,
            ..WatchdogConfig::default()
        },
        ..CoordinatorConfig::default()
    });
    let out = coord.solve_blocking(
        SolveRequest::builder(10, Arc::new(x), y)
            .backend(SolverKind::Bak)
            .opts(SolveOptions::builder().max_sweeps(50).tol(0.0).check_every(1).build())
            .job_id("doomed-recovery")
            .build(),
    );
    match out.report {
        Err(SolverError::NumericalBreakdown { detail, sweeps }) => {
            assert!(detail.contains("stagnating"), "{detail}");
            assert!(sweeps >= 1);
        }
        other => panic!("want NumericalBreakdown, got {other:?}"),
    }
    coord.shutdown();
}

/// Hand-rolled legacy v1 `.sbck` bytes: version byte 1, bare column-major
/// payload, no per-chunk CRC words.
fn write_v1_sbck(x: &Mat, chunk_cols: usize, path: &std::path::Path) {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&MAGIC);
    bytes.extend_from_slice(&[1u8, 0, 0, 0]);
    bytes.extend_from_slice(&(x.rows() as u64).to_le_bytes());
    bytes.extend_from_slice(&(x.cols() as u64).to_le_bytes());
    bytes.extend_from_slice(&(chunk_cols as u64).to_le_bytes());
    for &v in x.as_slice() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).expect("write v1 file");
}

#[test]
fn v1_sbck_files_solve_identically_after_the_v2_bump() {
    // Pre-CRC files written by older builds must keep solving — and land
    // on the exact same bits as the in-memory path, because v1 chunks are
    // the same column slices with no integrity words interleaved.
    let (x, _, y) = planted(4555, 300, 18);
    let path = temp_chunk_path("v1_solve_compat");
    write_v1_sbck(&x, 5, &path);
    let sm = StreamedMatrix::open(&path).expect("v1 header accepted");
    assert_eq!(sm.version(), 1);

    let opts = SolveOptions::builder().max_sweeps(12).tol(0.0).check_every(1).build();
    let solver = solver_for(SolverKind::Bak).unwrap();
    let mem = solver.solve(&Problem::new(&x, &y).unwrap(), &opts).unwrap();
    let streamed = solver
        .solve(&Problem::new_streamed(&sm, &y).unwrap(), &opts)
        .expect("v1 streamed solve");
    assert_eq!(streamed.a, mem.a, "v1 streamed solve must match in-memory bitwise");
    let _ = std::fs::remove_file(&path);
}
