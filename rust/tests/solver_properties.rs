//! Property-based tests over the solver family's invariants
//! (in-repo `util::prop` framework; see DESIGN.md).
//!
//! The properties are the paper's claims, stated over RANDOM systems:
//!  P1. Theorem 1: a SolveBak sweep never increases the squared residual.
//!  P2. After the column-j step, the residual is orthogonal to x_j.
//!  P3. The exit invariant e == y - X a holds for every solver.
//!  P4. Consistent systems are solved to (near) machine accuracy.
//!  P5. thr=1 BAKP is exactly BAK.
//!  P6. SolveBakF never selects a feature twice and never increases the
//!      residual with an added feature.
//!  P7. Zero columns are never touched.
//!  P8. BAK solutions of tall systems match QR least squares.

use solvebak::baselines::qr::lstsq_qr;
use solvebak::linalg::{blas1, residual, Mat};
use solvebak::solver::{self, BakfOptions, SolveOptions};
use solvebak::util::prop::{forall, DimCase};
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn system(c: &DimCase, noise: f32) -> (Mat, Vec<f32>) {
    let mut rng = Rng::seed(c.seed);
    let x = Mat::randn(&mut rng, c.obs, c.vars);
    let mut y: Vec<f32> = if noise < 0.0 {
        // Pure-noise (inconsistent) target.
        (0..c.obs).map(|_| rng.normal_f32()).collect()
    } else {
        let a: Vec<f32> = (0..c.vars).map(|_| rng.normal_f32()).collect();
        x.matvec(&a)
    };
    if noise > 0.0 {
        for v in y.iter_mut() {
            *v += noise * rng.normal_f32();
        }
    }
    (x, y)
}

#[test]
fn p1_sweep_monotone_residual() {
    forall(
        101,
        60,
        |rng| DimCase::draw(rng, 120, 40),
        |c| {
            let (x, y) = system(c, -1.0);
            let mut o = SolveOptions::default();
            o.tol = 0.0;
            o.max_sweeps = 8;
            let rep = solver::solve_bak(&x, &y, &o);
            let r0 = blas1::sum_sq_f64(&y);
            let mut prev = r0;
            for (k, &r) in rep.history.iter().enumerate() {
                if r > prev * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!("sweep {k}: {r} > {prev}"));
                }
                prev = r;
            }
            Ok(())
        },
    );
}

#[test]
fn p2_column_step_orthogonalizes() {
    forall(
        102,
        60,
        |rng| DimCase::draw(rng, 100, 20),
        |c| {
            let (x, y) = system(c, -1.0);
            let j = c.seed as usize % c.vars;
            let nrm = blas1::nrm2_sq(x.col(j));
            if nrm == 0.0 {
                return Ok(());
            }
            let mut e = y.clone();
            blas1::cd_step(x.col(j), &mut e, 1.0 / nrm);
            let d = blas1::dot(x.col(j), &e).abs();
            let scale = blas1::nrm2(x.col(j)) * blas1::nrm2(&e) + 1e-6;
            if d / scale > 1e-4 {
                return Err(format!("<x_j,e'> = {d} (scale {scale})"));
            }
            Ok(())
        },
    );
}

#[test]
fn p3_exit_invariant_all_solvers() {
    forall(
        103,
        40,
        |rng| DimCase::draw(rng, 100, 24),
        |c| {
            let (x, y) = system(c, 0.2);
            let mut o = SolveOptions::default();
            o.max_sweeps = 20;
            o.thr = (c.vars / 4).max(1);
            for (name, rep) in [
                ("bak", solver::solve_bak(&x, &y, &o)),
                ("bakp", solver::solve_bakp(&x, &y, &o)),
            ] {
                let fresh = residual(&x, &y, &rep.a);
                let num: f64 = fresh
                    .iter()
                    .zip(&rep.e)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let den = 1.0 + blas1::nrm2(&fresh) as f64;
                if num / den > 1e-3 {
                    return Err(format!("{name}: e drifted from y-Xa by {num}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p4_consistent_systems_solved() {
    forall(
        104,
        25,
        |rng| {
            // Tall systems (the paper's winning regime).
            let mut c = DimCase::draw(rng, 300, 24);
            c.obs = c.obs.max(c.vars * 4);
            c
        },
        |c| {
            let (x, y) = system(c, 0.0);
            let rep = solver::solve_bak(&x, &y, &SolveOptions::accurate());
            if rep.rel_residual() > 1e-4 {
                return Err(format!("rel residual {}", rep.rel_residual()));
            }
            Ok(())
        },
    );
}

#[test]
fn p5_thr_one_equals_bak() {
    forall(
        105,
        30,
        |rng| DimCase::draw(rng, 80, 16),
        |c| {
            let (x, y) = system(c, 0.3);
            let mut o = SolveOptions::default();
            o.thr = 1;
            o.max_sweeps = 4;
            o.tol = 0.0;
            let rp = solver::solve_bakp(&x, &y, &o);
            let rs = solver::solve_bak(&x, &y, &o);
            for (k, (p, s)) in rp.a.iter().zip(&rs.a).enumerate() {
                if (p - s).abs() > 1e-5 * (1.0 + s.abs()) {
                    return Err(format!("a[{k}]: {p} vs {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p6_feature_selection_invariants() {
    forall(
        106,
        25,
        |rng| {
            let mut c = DimCase::draw(rng, 150, 20);
            c.obs = c.obs.max(40);
            c.vars = c.vars.max(4);
            c
        },
        |c| {
            let (x, y) = system(c, 0.5);
            let k = (c.vars / 2).max(2);
            let rep = solver::select_features_bakf(
                &x,
                &y,
                &BakfOptions { max_feat: k, ..Default::default() },
            );
            let mut seen = std::collections::HashSet::new();
            for &j in &rep.selected {
                if !seen.insert(j) {
                    return Err(format!("feature {j} selected twice"));
                }
                if j >= c.vars {
                    return Err(format!("feature {j} out of range"));
                }
            }
            for (i, w) in rep.history.windows(2).enumerate() {
                if w[1] > w[0] * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!("round {}: residual rose {} -> {}", i + 1, w[0], w[1]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p7_zero_columns_untouched() {
    forall(
        107,
        30,
        |rng| DimCase::draw(rng, 60, 12),
        |c| {
            let mut rng = Rng::seed(c.seed);
            let mut x = Mat::randn(&mut rng, c.obs, c.vars);
            let dead = c.seed as usize % c.vars;
            x.col_mut(dead).fill(0.0);
            let y: Vec<f32> = (0..c.obs).map(|_| rng.normal_f32()).collect();
            let mut o = SolveOptions::default();
            o.max_sweeps = 10;
            let rep = solver::solve_bak(&x, &y, &o);
            if rep.a[dead] != 0.0 {
                return Err(format!("a[{dead}] = {} for zero column", rep.a[dead]));
            }
            let repp = solver::solve_bakp(&x, &y, &o);
            if repp.a[dead] != 0.0 {
                return Err(format!("bakp a[{dead}] = {}", repp.a[dead]));
            }
            Ok(())
        },
    );
}

#[test]
fn p8_tall_matches_qr_least_squares() {
    forall(
        108,
        20,
        |rng| {
            let mut c = DimCase::draw(rng, 200, 12);
            c.obs = c.obs.max(c.vars * 8 + 8); // strongly tall
            c
        },
        |c| {
            let (x, y) = system(c, 0.5);
            let mut o = SolveOptions::default();
            o.max_sweeps = 4000;
            o.tol = 0.0; // run to stall (LS optimum)
            o.check_every = 10;
            let rep = solver::solve_bak(&x, &y, &o);
            let a_qr = match lstsq_qr(&x, &y) {
                Ok(a) => a,
                Err(_) => return Ok(()), // rank-deficient draw: skip
            };
            let err = rel_l2(&rep.a, &a_qr);
            if err > 2e-2 {
                return Err(format!("CD vs QR coefficient gap {err}"));
            }
            Ok(())
        },
    );
}
