//! Chaos-style robustness integration: a TCP server under fault injection,
//! admission control, and deadlines must give every client a structured
//! reply — never a hang, never a dropped connection — and drain cleanly.
//!
//! Fault plans are process-global, so every test here serializes on one
//! mutex (this binary is its own process, so arming worker panics cannot
//! leak into the library's unit tests).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};

use solvebak::api::SolverError;
use solvebak::client::{Client, RetryPolicy};
use solvebak::coordinator::server::{error_kind, Server};
use solvebak::coordinator::{Coordinator, CoordinatorConfig};
use solvebak::robust::faults::{self, FaultPlan};
use solvebak::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn start(config: CoordinatorConfig) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(Coordinator::start(config));
    let server = Server::bind(coord.clone(), 0).expect("bind");
    (coord, server)
}

/// A small consistent dense system as one request line.
fn solve_line(id: u64, deadline_ms: Option<u64>) -> String {
    let deadline = deadline_ms
        .map(|ms| format!(r#", "deadline_ms": {ms}"#))
        .unwrap_or_default();
    format!(
        r#"{{"v": 1, "id": {id}, "backend": "bak", "obs": 4, "vars": 2, "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1], "sweeps": 200, "tol": 1e-7{deadline}}}"#
    )
}

fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(line.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    let mut r = BufReader::new(s);
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).expect("structured json reply")
}

fn metric(coord: &Coordinator, name: &str) -> f64 {
    coord.metrics().to_json().get(name).and_then(Json::as_f64).unwrap_or(0.0)
}

#[test]
fn burst_under_faults_every_client_gets_a_structured_reply() {
    let _g = serial();
    faults::install(&FaultPlan {
        worker_panic_every: 5,
        queue_stall_ms: 2,
        ..FaultPlan::default()
    });
    let (coord, server) = start(CoordinatorConfig {
        workers: 2,
        max_inflight: 4,
        max_queue_wait_ms: 50,
        ..CoordinatorConfig::default()
    });
    let addr = server.addr();

    // 8 clients x 6 requests, some deadline-armed, all through the
    // retrying client. Every request must come back as one JSON line with
    // a known shape — ok, or a structured error from the allowed set.
    let handles: Vec<_> = (0..8u64)
        .map(|t| {
            std::thread::spawn(move || {
                let mut client = Client::with_policy(
                    addr.to_string(),
                    RetryPolicy {
                        max_retries: 2,
                        base_ms: 2,
                        max_backoff_ms: 20,
                        budget_ms: 5_000,
                        jitter_seed: t,
                    },
                );
                let mut replies = Vec::new();
                for i in 0..6u64 {
                    let id = t * 100 + i;
                    let deadline = if i % 3 == 2 { Some(1) } else { None };
                    let req = Json::parse(&solve_line(id, deadline)).unwrap();
                    replies.push(client.request(&req).expect("a structured reply"));
                }
                replies
            })
        })
        .collect();
    let mut replies: Vec<Json> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client thread survives"))
        .collect();
    // Two guaranteed-expired requests so the deadline path always fires.
    for id in [900u64, 901] {
        replies.push(roundtrip(addr, &solve_line(id, Some(0))));
    }

    assert_eq!(replies.len(), 50);
    for j in &replies {
        let ok = j.get("ok").and_then(Json::as_bool).expect("every reply carries ok");
        if !ok {
            let kind = j.get("error_kind").and_then(Json::as_str).expect("typed error");
            assert!(
                ["deadline_exceeded", "overloaded", "service", "backend"].contains(&kind),
                "unexpected error_kind {kind}: {j:?}"
            );
        }
    }

    // The deadline counter moved, and injected panics were contained by
    // the pool (workers survive; panicked jobs answer as service errors).
    // >= 1, not 2: a deadline-0 job can instead land on an injected
    // worker panic (and answer as a service error), but never both.
    assert!(metric(&coord, "jobs_deadline_exceeded") >= 1.0);
    assert!(metric(&coord, "worker_panics") >= 1.0);

    // Graceful drain: shutdown over the wire, then joining the accept
    // thread (and its per-connection handlers) must terminate.
    faults::clear();
    let bye = roundtrip(addr, r#"{"cmd": "shutdown"}"#);
    assert_eq!(bye.get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn saturated_server_sheds_with_retry_hint() {
    let _g = serial();
    // One permit, no queue wait, and a 200ms scheduler stall: the permit
    // cannot be released faster than one job per stall, so a burst of 5
    // back-to-back requests must shed at least 3.
    faults::install(&FaultPlan { queue_stall_ms: 200, ..FaultPlan::default() });
    let (coord, server) = start(CoordinatorConfig {
        workers: 1,
        max_inflight: 1,
        ..CoordinatorConfig::default()
    });
    let addr = server.addr();

    let handles: Vec<_> = (0..5u64)
        .map(|i| std::thread::spawn(move || roundtrip(addr, &solve_line(i, None))))
        .collect();
    let replies: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    faults::clear();

    let shed: Vec<&Json> = replies
        .iter()
        .filter(|j| j.get("error_kind").and_then(Json::as_str) == Some("overloaded"))
        .collect();
    assert!(shed.len() >= 3, "want >=3 shed replies, got {}", shed.len());
    for j in &shed {
        let hint = j.get("retry_after_ms").and_then(Json::as_f64).expect("backoff hint");
        assert!((25.0..=5000.0).contains(&hint), "hint {hint} out of range");
    }
    // Admitted requests still solved correctly.
    assert!(replies.iter().any(|j| j.get("ok").unwrap().as_bool() == Some(true)));
    assert!(metric(&coord, "jobs_shed") >= 3.0);
    server.stop();
}

#[test]
fn degraded_mode_answers_instead_of_shedding() {
    let _g = serial();
    faults::install(&FaultPlan { queue_stall_ms: 200, ..FaultPlan::default() });
    let (coord, server) = start(CoordinatorConfig {
        workers: 1,
        max_inflight: 1,
        degraded_sweeps: Some(2),
        ..CoordinatorConfig::default()
    });
    let addr = server.addr();

    let handles: Vec<_> = (0..4u64)
        .map(|i| std::thread::spawn(move || roundtrip(addr, &solve_line(i, None))))
        .collect();
    let replies: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    faults::clear();

    // Nobody was shed; everyone got an answer; the overflow was served in
    // degraded (sweep-clamped) mode and flagged as such.
    for j in &replies {
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
    }
    let degraded = replies
        .iter()
        .filter(|j| j.get("degraded").and_then(Json::as_bool) == Some(true))
        .count();
    assert!(degraded >= 1, "no degraded replies in {replies:?}");
    assert_eq!(metric(&coord, "jobs_shed"), 0.0);
    assert!(metric(&coord, "degraded_solves") >= 1.0);
    server.stop();
}

#[test]
fn no_fault_solves_are_bit_identical() {
    let _g = serial();
    faults::clear();
    let (_coord, server) = start(CoordinatorConfig {
        workers: 1,
        ..CoordinatorConfig::default()
    });
    let a = roundtrip(server.addr(), &solve_line(1, None));
    let b = roundtrip(server.addr(), &solve_line(2, None));
    assert_eq!(a.get("ok").unwrap().as_bool(), Some(true), "{a:?}");
    // Same request, no faults: the solve is deterministic down to the bit
    // (only id/timing fields may differ).
    assert_eq!(a.get("a"), b.get("a"));
    assert_eq!(a.get("sweeps"), b.get("sweeps"));
    assert_eq!(a.get("rel_residual"), b.get("rel_residual"));
    server.stop();
}

#[test]
fn error_kind_table_is_exhaustive_over_solver_error() {
    // One value per SolverError variant; the wire table must give each a
    // distinct stable kind. (The match inside error_kind() is exhaustive,
    // so a new variant without a wire kind is already a compile error —
    // this test pins the *names* so they cannot silently change.)
    let every: Vec<(SolverError, &str)> = vec![
        (SolverError::Shape("bad".into()), "shape"),
        (SolverError::NonFinite { what: "x" }, "non_finite"),
        (SolverError::NeedsSquare { obs: 3, vars: 2 }, "needs_square"),
        (SolverError::RankDeficient { column: 1 }, "rank_deficient"),
        (
            SolverError::Unavailable { backend: "pjrt".into(), reason: "no engine".into() },
            "unavailable",
        ),
        (SolverError::UnknownKind("gpu4000".into()), "unknown_kind"),
        (
            SolverError::Backend { backend: "bak".into(), reason: "boom".into() },
            "backend",
        ),
        (SolverError::Service("shut down".into()), "service"),
        (SolverError::InvalidInput("half-written".into()), "invalid_input"),
        (
            SolverError::DeadlineExceeded { best: vec![0.0], rel_residual: 1.0, sweeps: 0 },
            "deadline_exceeded",
        ),
        (SolverError::Overloaded { retry_after_ms: 50 }, "overloaded"),
        (SolverError::Unsupported("v2".into()), "unsupported"),
        (
            SolverError::CorruptData { chunk: 3, expected: 0xDEAD_BEEF, actual: 0x0BAD_F00D },
            "corrupt_data",
        ),
        (
            SolverError::NumericalBreakdown { detail: "residual is NaN".into(), sweeps: 7 },
            "numerical_breakdown",
        ),
    ];
    let mut kinds = std::collections::BTreeSet::new();
    for (err, want) in &every {
        assert_eq!(&error_kind(err), want, "{err:?}");
        kinds.insert(*want);
    }
    // All kinds distinct: the discriminant really discriminates.
    assert_eq!(kinds.len(), every.len());
}
