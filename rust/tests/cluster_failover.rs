//! Distributed shard cluster integration: loopback and TCP workers
//! exercised end-to-end through the crate's public surface — the same
//! paths CI's `cluster-smoke` job drives across real processes.
//!
//! The acceptance bar is **bit-identity**: for a fixed `(seed, shards)`
//! a clustered solve must reproduce `solve_kaczmarz_par` /
//! `solve_bak_par` exactly — same coefficients, same residual vector,
//! same history, same stop reason — no matter how many workers serve
//! the shards, and even when a worker is killed mid-solve and its
//! shards move to survivors.

use std::sync::Arc;

use solvebak::api::{SolverError, SolverKind};
use solvebak::cluster::{ClusterDriver, Membership, WorkerCore, WorkerServer};
use solvebak::linalg::Mat;
use solvebak::parallel::{solve_bak_par, solve_kaczmarz_par};
use solvebak::solver::{ColumnOrder, SolveOptions, SolveReport};
use solvebak::util::rng::Rng;

fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);
    (x, y)
}

fn assert_reports_identical(cluster: &SolveReport, local: &SolveReport, ctx: &str) {
    assert_eq!(cluster.a, local.a, "{ctx}: coefficients must match bit-for-bit");
    assert_eq!(cluster.e, local.e, "{ctx}: residuals must match bit-for-bit");
    assert_eq!(cluster.history, local.history, "{ctx}: history must match");
    assert_eq!(cluster.sweeps, local.sweeps, "{ctx}");
    assert_eq!(cluster.stop, local.stop, "{ctx}");
}

/// The cluster answer is a function of `(seed, shards)` only — 1, 2, and
/// 4 workers all reproduce the in-process `kaczmarz_par` run exactly.
#[test]
fn kaczmarz_bit_identical_across_1_2_4_workers() {
    let (x, y) = planted(101, 96, 8);
    let opts = SolveOptions::builder().max_sweeps(24).tol(1e-10).threads(4).build();
    let local = solve_kaczmarz_par(&x, &y, &opts);
    for workers in [1usize, 2, 4] {
        let (membership, _t) = Membership::loopback(workers, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let out = driver
            .solve(SolverKind::KaczmarzPar, &x, &y, &opts, None)
            .expect("cluster solve");
        assert!(!out.resharded);
        assert_eq!(out.sync_rounds as usize, local.sweeps);
        assert_reports_identical(&out.report, &local, &format!("{workers} worker(s)"));
    }
}

/// Same invariant for the column-sharded `bak_par`, including the
/// shuffled column order (whose RNG streams must also be worker-count
/// independent).
#[test]
fn bak_bit_identical_across_1_2_4_workers() {
    let (x, y) = planted(102, 80, 12);
    let opts = SolveOptions::builder()
        .max_sweeps(30)
        .tol(1e-10)
        .threads(3)
        .order(ColumnOrder::Shuffled)
        .build();
    let local = solve_bak_par(&x, &y, &opts);
    for workers in [1usize, 2, 4] {
        let (membership, _t) = Membership::loopback(workers, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let out = driver
            .solve(SolverKind::BakPar, &x, &y, &opts, None)
            .expect("cluster solve");
        assert!(!out.resharded);
        assert_reports_identical(&out.report, &local, &format!("{workers} worker(s)"));
    }
}

/// Kill one of two workers mid-sweep: the driver must mark it dead,
/// move its shards to the survivor (warm-started from the last synced
/// iterate), surface `resharded`, and still land on the bit-identical
/// answer.
#[test]
fn killing_a_worker_mid_sweep_reshards_without_changing_the_answer() {
    let (x, y) = planted(103, 72, 6);
    let opts = SolveOptions::builder().max_sweeps(25).tol(1e-10).threads(4).build();
    let (membership, transports) = Membership::loopback(2, 0);
    let driver = ClusterDriver::new(Arc::new(membership));
    // A few successful rounds first, so the death lands mid-solve with
    // shard state already cached on the doomed worker.
    transports[1].fail_after_requests(5);
    let out = driver
        .solve(SolverKind::KaczmarzPar, &x, &y, &opts, None)
        .expect("survivors finish the job");
    assert!(out.resharded, "worker loss must surface as a reshard");
    assert_eq!(driver.membership().alive_count(), 1);
    let local = solve_kaczmarz_par(&x, &y, &opts);
    assert_reports_identical(&out.report, &local, "post-reshard");
    // The survivor keeps answering follow-up jobs alone.
    let out2 = driver
        .solve(SolverKind::KaczmarzPar, &x, &y, &opts, None)
        .expect("solo survivor");
    assert!(!out2.resharded, "no further loss, no further reshard");
    assert_reports_identical(&out2.report, &local, "solo survivor");
}

/// Losing every worker is a typed service error, not a hang or a panic.
#[test]
fn losing_every_worker_is_a_typed_service_error() {
    let (x, y) = planted(104, 24, 4);
    let opts = SolveOptions::builder().max_sweeps(10).threads(2).build();
    let (membership, transports) = Membership::loopback(2, 0);
    for t in &transports {
        t.fail_after_requests(0);
    }
    let driver = ClusterDriver::new(Arc::new(membership));
    let err = driver
        .solve(SolverKind::KaczmarzPar, &x, &y, &opts, None)
        .unwrap_err();
    assert!(matches!(err, SolverError::Service(_)), "{err:?}");
}

/// Full TCP loop: two real `WorkerServer`s on ephemeral ports, a
/// `Membership::connect` roster, and bit-identity through actual
/// sockets — the two-terminal quickstart from the crate docs, in one
/// process.
#[test]
fn tcp_workers_serve_a_bit_identical_sharded_solve() {
    let w1 = WorkerServer::bind(Arc::new(WorkerCore::new("it-w1")), 0).expect("bind w1");
    let w2 = WorkerServer::bind(Arc::new(WorkerCore::new("it-w2")), 0).expect("bind w2");
    let addrs = vec![w1.addr().to_string(), w2.addr().to_string()];
    let membership = Membership::connect(&addrs);
    assert_eq!(membership.alive_count(), 2, "join probe reaches both workers");
    let driver = ClusterDriver::new(Arc::new(membership));

    let (x, y) = planted(105, 64, 6);
    let opts = SolveOptions::builder().max_sweeps(15).tol(1e-10).threads(3).build();
    let out = driver
        .solve(SolverKind::KaczmarzPar, &x, &y, &opts, None)
        .expect("tcp cluster solve");
    let local = solve_kaczmarz_par(&x, &y, &opts);
    assert_reports_identical(&out.report, &local, "tcp");
    assert!(!out.resharded);
    w1.stop();
    w2.stop();
}
