//! Integration: the full L1->L2->L3 path.
//!
//! These tests load the REAL artifacts produced by `make artifacts`
//! (python/compile/aot.py) into the PJRT engine and check that the
//! AOT-compiled sweeps agree with the native Rust solvers — the
//! cross-layer correctness contract of the whole system.
//!
//! Skipped (cleanly) when `artifacts/manifest.json` is missing so that
//! `cargo test` works before `make artifacts`; CI runs `make test` which
//! builds artifacts first.

use solvebak::linalg::{blas1, Mat};
use solvebak::runtime::{ArtifactKind, Engine};
use solvebak::solver::{self, SolveOptions};
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a);
    (x, y, a)
}

#[test]
fn engine_loads_and_warms_up() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    assert!(eng.platform().to_lowercase().contains("cpu"));
    let n = eng.warmup().expect("warmup compiles every artifact");
    assert!(n >= 4, "expected the full artifact menu, got {n}");
}

#[test]
fn pjrt_colnorms_matches_native() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, _, _) = planted(1, 200, 50);
    let got = eng.colnorms_inv_pjrt(&x).expect("pjrt colnorms");
    let want = solver::colnorms_inv(&x);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

#[test]
fn pjrt_bakp_sweep_matches_native_solver() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    // Exact bucket shape: 256x64, artifact thr=32.
    let (x, y, _) = planted(2, 256, 64);
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 1;
    opts.tol = 0.0;
    opts.thr = 32; // must match the artifact's baked width
    let pjrt = eng.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("pjrt solve");
    let native = solver::solve_bakp(&x, &y, &opts);
    // One sweep, same block width, same stale-error semantics -> same a.
    assert!(
        rel_l2(&pjrt.report.a, &native.a) < 1e-3,
        "one-sweep mismatch: {}",
        rel_l2(&pjrt.report.a, &native.a)
    );
    assert_eq!(pjrt.artifact, "bakp_sweep_256x64");
    assert!(pjrt.pad_overhead.abs() < 1e-12, "exact-fit has no padding");
}

#[test]
fn pjrt_full_solve_converges_to_truth() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, y, a_true) = planted(3, 256, 64);
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 300;
    opts.tol = 1e-6;
    let out = eng.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("pjrt solve");
    assert!(out.report.converged() || out.report.rel_residual() < 1e-4,
            "stop={:?} rel={}", out.report.stop, out.report.rel_residual());
    assert!(rel_l2(&out.report.a, &a_true) < 1e-2,
            "coef err {}", rel_l2(&out.report.a, &a_true));
}

#[test]
fn pjrt_routes_smaller_problem_with_padding() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    // 200x40 fits in the 256x64 bucket with zero padding. (Tall enough
    // that the artifact's baked thr=32 stale blocks still converge — the
    // paper's §6 caveat; see the thr-sweep ablation bench.)
    let (x, y, a_true) = planted(4, 200, 40);
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 400;
    opts.tol = 1e-6;
    let out = eng.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("pjrt solve");
    assert_eq!(out.artifact, "bakp_sweep_256x64");
    assert!(out.pad_overhead > 0.0);
    assert_eq!(out.report.a.len(), 40, "solution truncated to true vars");
    assert!(rel_l2(&out.report.a, &a_true) < 1e-2,
            "padded solve err {}", rel_l2(&out.report.a, &a_true));
}

#[test]
fn pjrt_sequential_bak_sweep_artifact_matches_native_bak() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, y, _) = planted(5, 256, 64);
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 1;
    opts.tol = 0.0;
    let pjrt = eng.solve(&x, &y, &opts, ArtifactKind::BakSweep).expect("pjrt bak");
    let native = solver::solve_bak(&x, &y, &opts);
    assert!(
        rel_l2(&pjrt.report.a, &native.a) < 1e-3,
        "sequential sweep mismatch: {}",
        rel_l2(&pjrt.report.a, &native.a)
    );
}

#[test]
fn pjrt_feature_scores_match_native_scoring() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, y, _) = planted(6, 256, 64);
    let scores = eng.feature_scores(&x, &y).expect("pjrt scores");
    // Native: <x_j,e>^2 / <x_j,x_j>.
    let g = x.matvec_t(&y);
    let cninv = solver::colnorms_inv(&x);
    for j in 0..64 {
        let want = g[j] * g[j] * cninv[j];
        assert!(
            (scores[j] - want).abs() < 1e-2 * (1.0 + want.abs()),
            "score[{j}] {} vs {}",
            scores[j],
            want
        );
    }
}

#[test]
fn pjrt_history_monotone() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let mut rng = Rng::seed(7);
    let x = Mat::randn(&mut rng, 256, 64);
    let y: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect(); // inconsistent
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 20;
    opts.tol = 0.0;
    let out = eng.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("solve");
    for w in out.report.history.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-5), "Theorem 1 via PJRT: {w:?}");
    }
}

#[test]
fn pjrt_rejects_oversized_problem() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, y, _) = planted(8, 16, 2048); // vars beyond any bucket
    let err = eng
        .solve(&x, &y, &SolveOptions::default(), ArtifactKind::BakpSweep)
        .unwrap_err();
    assert!(err.to_string().contains("no bakp_sweep artifact"), "{err}");
}

#[test]
fn coordinator_pjrt_backend_end_to_end() {
    let dir = require_artifacts!();
    use solvebak::coordinator::{Backend, Coordinator, CoordinatorConfig, SolveRequest};
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        artifact_dir: Some(dir),
        ..CoordinatorConfig::default()
    });
    assert!(coord.engine().is_some(), "engine must load");
    let (x, y, a_true) = planted(9, 256, 64);
    let mut req = SolveRequest::new(77, std::sync::Arc::new(x), y);
    req.backend = Backend::Pjrt;
    req.opts.max_sweeps = 300;
    let out = coord.solve_blocking(req);
    assert_eq!(out.id, 77);
    assert_eq!(out.backend, Backend::Pjrt);
    let rep = out.report.expect("pjrt solve via coordinator");
    assert!(rel_l2(&rep.a, &a_true) < 1e-2);
    coord.shutdown();
}

#[test]
fn pjrt_residual_tracks_native_residual_over_sweeps() {
    let dir = require_artifacts!();
    let eng = Engine::new(&dir).expect("engine");
    let (x, y, _) = planted(10, 256, 64);
    let mut opts = SolveOptions::default();
    opts.max_sweeps = 5;
    opts.tol = 0.0;
    opts.thr = 32;
    let pjrt = eng.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("solve");
    let native = solver::solve_bakp(&x, &y, &opts);
    assert_eq!(pjrt.report.history.len(), native.history.len());
    for (p, n) in pjrt.report.history.iter().zip(&native.history) {
        let denom = 1.0 + n.abs();
        assert!(((p - n) / denom).abs() < 1e-2, "history diverged: {p} vs {n}");
    }
    // And the final residual vector itself agrees with e = y - Xa.
    let fresh = solvebak::linalg::residual(&x, &y, &pjrt.report.a);
    let diff: f64 = fresh
        .iter()
        .zip(&pjrt.report.e)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    assert!(diff < 1e-2 * (1.0 + blas1::nrm2(&fresh) as f64));
}
