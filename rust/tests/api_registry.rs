//! Contract tests over the solver registry: every registered
//! implementation must solve a consistent system through the shared
//! `Solver` trait, and the `SolverKind` namespace must round-trip through
//! its string form (the CLI/wire encoding).

use solvebak::api::{registry, solver_for, Problem, SolverError, SolverKind};
use solvebak::bench::workload::{SparseWorkload, WorkloadSpec};
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::sparse::CscMat;
use solvebak::util::rng::Rng;

fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>) {
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a);
    (x, y)
}

fn planted_sparse(seed: u64, obs: usize, vars: usize, density: f64) -> (CscMat, Vec<f32>) {
    let w = SparseWorkload::uniform(WorkloadSpec::new(obs, vars, seed), density);
    (w.x, w.y)
}

#[test]
fn every_registered_solver_solves_a_consistent_system() {
    // The shared tall workload; square-only solvers get the square
    // variant of the same draw (their capabilities reject tall).
    let (tall_x, tall_y) = planted(42, 160, 12);
    let (sq_x, sq_y) = planted(42, 24, 24);
    let opts = SolveOptions::builder()
        .max_sweeps(5000)
        .tol(1e-5)
        .thr(4)
        .check_every(1)
        .build();

    for solver in registry() {
        let caps = solver.capabilities();
        let (x, y) = if caps.needs_square { (&sq_x, &sq_y) } else { (&tall_x, &tall_y) };
        let problem = Problem::new(x, y).expect("valid planted system");
        match solver.solve(&problem, &opts) {
            Ok(rep) => {
                assert!(
                    rep.rel_residual() < 1e-3,
                    "{}: rel_residual {} too large",
                    solver.name(),
                    rep.rel_residual()
                );
                // The exit invariant e == y - X a holds across the trait.
                let fresh = solvebak::linalg::residual(x, y, &rep.a);
                for (f, g) in fresh.iter().zip(&rep.e) {
                    assert!((f - g).abs() < 1e-3, "{}: stale residual", solver.name());
                }
            }
            // PJRT registers detached (no artifacts in the test env); any
            // other backend has no excuse.
            Err(SolverError::Unavailable { .. }) => {
                assert_eq!(solver.kind(), SolverKind::Pjrt, "{} unavailable", solver.name());
            }
            Err(e) => panic!("{} failed: {e}", solver.name()),
        }
    }
}

#[test]
fn registry_rejects_invalid_problems_without_panicking() {
    let (x, _) = planted(43, 30, 5);
    let bad_y = vec![0.0f32; 7]; // wrong length
    assert!(matches!(Problem::new(&x, &bad_y), Err(SolverError::Shape(_))));

    // Wide system: solvers that declare !supports_wide must return a
    // typed error through the trait, not panic.
    let (wide_x, wide_y) = planted(44, 8, 40);
    let p = Problem::new(&wide_x, &wide_y).unwrap();
    for solver in registry() {
        if !solver.capabilities().supports_wide {
            assert!(
                solver.solve(&p, &SolveOptions::default()).is_err(),
                "{} accepted a wide system it does not support",
                solver.name()
            );
        }
    }
}

#[test]
fn every_registered_solver_answers_sparse_problems() {
    // Sparse-native kinds (supports_sparse) run O(nnz); everything else
    // is exercised through the densification fallback — either way the
    // shared trait must produce a correct report, never a panic.
    let (tall_x, tall_y) = planted_sparse(45, 200, 16, 0.2);
    let (sq_x, sq_y) = planted_sparse(46, 24, 24, 0.4);
    let opts = SolveOptions::builder()
        .max_sweeps(5000)
        .tol(1e-5)
        .thr(4)
        .check_every(1)
        .build();

    let mut native = 0;
    let mut densified = 0;
    for solver in registry() {
        let caps = solver.capabilities();
        let (x, y) = if caps.needs_square { (&sq_x, &sq_y) } else { (&tall_x, &tall_y) };
        let problem = Problem::new_sparse(x, y).expect("valid planted sparse system");
        match solver.solve(&problem, &opts) {
            Ok(rep) => {
                assert!(
                    rep.rel_residual() < 1e-3,
                    "{}: rel_residual {} too large on sparse input",
                    solver.name(),
                    rep.rel_residual()
                );
                if caps.supports_sparse {
                    native += 1;
                } else {
                    densified += 1;
                }
            }
            Err(SolverError::Unavailable { .. }) => {
                assert_eq!(solver.kind(), SolverKind::Pjrt, "{} unavailable", solver.name());
            }
            Err(e) => panic!("{} failed on sparse input: {e}", solver.name()),
        }
    }
    // Both paths were exercised: the native sextet and the fallback.
    assert_eq!(
        native, 6,
        "bak/bakp/bak_par/kaczmarz/kaczmarz_par/cgls solve natively"
    );
    assert!(densified >= 4, "dense-only backends answered via densification");
}

#[test]
fn kind_display_from_str_round_trip() {
    for kind in SolverKind::CONCRETE.into_iter().chain([SolverKind::Auto]) {
        let s = kind.to_string();
        let back: SolverKind = s.parse().expect("canonical name parses");
        assert_eq!(back, kind, "round trip failed for '{s}'");
    }
}

#[test]
fn registry_order_matches_concrete_kinds() {
    let kinds: Vec<SolverKind> = registry().iter().map(|s| s.kind()).collect();
    assert_eq!(kinds, SolverKind::CONCRETE.to_vec());
    for &k in &SolverKind::CONCRETE {
        assert!(solver_for(k).is_some(), "{k} missing from solver_for");
    }
    assert!(solver_for(SolverKind::Auto).is_none());
}

#[test]
fn aliases_and_unknowns() {
    assert_eq!("lapack".parse::<SolverKind>().unwrap(), SolverKind::Qr);
    assert_eq!("QR".parse::<SolverKind>().unwrap(), SolverKind::Qr);
    assert_eq!("bak-multi".parse::<SolverKind>().unwrap(), SolverKind::BakMulti);
    assert_eq!("bak-par".parse::<SolverKind>().unwrap(), SolverKind::BakPar);
    assert_eq!(
        "kaczmarz-par".parse::<SolverKind>().unwrap(),
        SolverKind::KaczmarzPar
    );
    let err = "warp-drive".parse::<SolverKind>().unwrap_err();
    assert!(matches!(err, SolverError::UnknownKind(_)));
    assert!(err.to_string().contains("warp_drive") || err.to_string().contains("warp-drive"));
}
