//! Figure 2 reproduction: speed-up of SolveBakF feature selection versus
//! forward stepwise regression, over a grid of (obs, vars, max_feat).
//!
//! Stepwise refits EVERY candidate feature every round (O(vars k^2 obs)
//! per round); SolveBakF scores all features with one fused pass. The
//! speed-up grows with vars — the paper's Figure-2 shape.
//!
//! Run: `cargo bench --bench figure2_feature_selection [-- --samples N]`

use solvebak::baselines::stepwise_select;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::solver::{select_features_bakf, BakfOptions};
use solvebak::util::alloc::CountingAlloc;
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };

    // Grid: growing feature counts at fixed obs, plus one taller config.
    let grid_full: &[(usize, usize, usize)] = &[
        // (obs, vars, max_feat)
        (2_000, 50, 5),
        (2_000, 100, 5),
        (2_000, 200, 5),
        (2_000, 400, 5),
        (2_000, 100, 10),
        (2_000, 200, 10),
        (10_000, 200, 5),
        (10_000, 400, 10),
    ];
    // --smoke: the three cheapest rows still show the vars trend.
    let grid = if smoke { &grid_full[..3] } else { grid_full };

    println!("# Figure 2 reproduction — SolveBakF vs stepwise regression");
    println!(
        "{:>7} {:>6} {:>5} | {:>12} {:>12} | {:>8} | {:>7} {:>7}",
        "obs", "vars", "k", "stepwise_ms", "bakf_ms", "speedup", "hitF", "hitS"
    );

    for &(obs, vars, k) in grid {
        let (w, support) =
            Workload::sparse_support(WorkloadSpec::new(obs, vars, 99), k, 0.05);

        let t_bakf = Summary::of(&sample(&cfg, || {
            std::hint::black_box(select_features_bakf(
                &w.x,
                &w.y,
                &BakfOptions { max_feat: k, ..Default::default() },
            ));
        }));
        let t_step = Summary::of(&sample(&cfg, || {
            std::hint::black_box(stepwise_select(&w.x, &w.y, k));
        }));

        // Quality: both methods should recover the planted support.
        let rep_f = select_features_bakf(&w.x, &w.y, &BakfOptions { max_feat: k, ..Default::default() });
        let rep_s = stepwise_select(&w.x, &w.y, k);
        let hits = |sel: &[usize]| sel.iter().filter(|j| support.contains(j)).count();
        let speedup = t_step.min / t_bakf.min;

        println!(
            "{:>7} {:>6} {:>5} | {:>12.2} {:>12.2} | {:>8.1} | {:>5}/{:<1} {:>5}/{:<1}",
            obs, vars, k,
            t_step.min * 1e3, t_bakf.min * 1e3,
            speedup,
            hits(&rep_f.selected), k, hits(&rep_s.selected), k,
        );
    }
    println!("# paper Figure 2: speed-up grows with vars (up to ~1e2-1e3); expect the same trend above.");
}
