//! Cluster-scaling bench: sharded `kaczmarz_par` / `bak_par` solves
//! through the [`solvebak::cluster`] driver over 1/2/4 loopback workers,
//! against the in-process reference at the same `(seed, shards)`.
//!
//! Loopback workers pay the full protocol cost — every shard round is
//! built, serialised, parsed, executed, serialised, and parsed back — so
//! the numbers isolate wire + merge overhead from socket latency. Each
//! row records wall time *and* the sync-round count (== sweeps
//! dispatched), because sync rounds are what a real network multiplies.
//!
//! This is also the CI artifact producer: `--out FILE` writes every row
//! as a JSON array — the `cluster-smoke` job runs it with
//! `--smoke --out BENCH_PR10.json` and uploads the artifact.
//!
//! Run: `cargo bench --bench cluster_scaling [-- --smoke] [--samples N]
//!       [--out FILE]`

use std::sync::Arc;

use solvebak::api::SolverKind;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::cluster::{ClusterDriver, Membership};
use solvebak::parallel;
use solvebak::solver::SolveOptions;
use solvebak::util::json::{Json, ObjBuilder};
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

struct Row {
    solver: &'static str,
    mode: String,
    obs: usize,
    vars: usize,
    shards: usize,
    workers: usize,
    seconds: f64,
    sync_rounds: u64,
    rel_residual: f64,
    sweeps: usize,
    bit_identical: bool,
}

impl Row {
    fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("solver", self.solver)
            .str("mode", self.mode.as_str())
            .num("obs", self.obs as f64)
            .num("vars", self.vars as f64)
            .num("shards", self.shards as f64)
            .num("workers", self.workers as f64)
            .num("seconds", self.seconds)
            .num("sync_rounds", self.sync_rounds as f64)
            .num("rel_residual", self.rel_residual)
            .num("sweeps", self.sweeps as f64)
            .bool("bit_identical", self.bit_identical)
            .build()
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    let out_path = args.get("out").map(str::to_string);

    let (obs, vars) = if smoke { (2_000, 64) } else { (20_000, 256) };
    let sweeps = if smoke { 4 } else { 8 };
    let shards = 4usize;
    let worker_axis = [1usize, 2, 4];

    let mut opts = SolveOptions::default();
    opts.max_sweeps = sweeps;
    opts.tol = 0.0;
    opts.threads = shards;

    let w = Workload::consistent(WorkloadSpec::new(obs, vars, 42));

    println!("# cluster scaling — {obs}x{vars}, {shards} shards, {sweeps} sweeps");
    println!(
        "{:<14} {:>18} | {:>10} {:>11} {:>12} {:>9}",
        "solver", "mode", "time_ms", "sync_rounds", "rel_resid", "identical"
    );
    let mut rows: Vec<Row> = Vec::new();

    for (kind, name, reference) in [
        (
            SolverKind::KaczmarzPar,
            "kaczmarz_par",
            parallel::solve_kaczmarz_par(&w.x, &w.y, &opts),
        ),
        (SolverKind::BakPar, "bak_par", parallel::solve_bak_par(&w.x, &w.y, &opts)),
    ] {
        // In-process reference row: the floor every worker count is
        // measured against.
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(match kind {
                SolverKind::KaczmarzPar => parallel::solve_kaczmarz_par(&w.x, &w.y, &opts),
                _ => parallel::solve_bak_par(&w.x, &w.y, &opts),
            });
        }));
        let local_ms = tm.min * 1e3;
        println!(
            "{:<14} {:>18} | {:>10.2} {:>11} {:>12.3e} {:>9}",
            name, "in-process", local_ms, "-", reference.rel_residual(), "-"
        );
        rows.push(Row {
            solver: name,
            mode: "in-process".into(),
            obs,
            vars,
            shards,
            workers: 0,
            seconds: tm.min,
            sync_rounds: 0,
            rel_residual: reference.rel_residual(),
            sweeps: reference.sweeps,
            bit_identical: true,
        });

        for &workers in &worker_axis {
            let (membership, _t) = Membership::loopback(workers, 0);
            let driver = ClusterDriver::new(Arc::new(membership));
            let out = driver.solve(kind, &w.x, &w.y, &opts, None).expect("cluster solve");
            let tm = Summary::of(&sample(&cfg, || {
                std::hint::black_box(
                    driver.solve(kind, &w.x, &w.y, &opts, None).expect("cluster solve"),
                );
            }));
            let identical = out.report.a == reference.a
                && out.report.e == reference.e
                && out.report.history == reference.history;
            println!(
                "{:<14} {:>18} | {:>10.2} {:>11} {:>12.3e} {:>9}",
                name,
                format!("{workers} loopback wkr"),
                tm.min * 1e3,
                out.sync_rounds,
                out.report.rel_residual(),
                identical
            );
            rows.push(Row {
                solver: name,
                mode: format!("loopback-{workers}"),
                obs,
                vars,
                shards,
                workers,
                seconds: tm.min,
                sync_rounds: out.sync_rounds,
                rel_residual: out.report.rel_residual(),
                sweeps: out.report.sweeps,
                bit_identical: identical,
            });
        }
    }

    if let Some(path) = out_path {
        let json = Json::Arr(rows.iter().map(Row::to_json).collect());
        std::fs::write(&path, json.to_string()).expect("write bench json");
        println!("# wrote {} rows to {path}", rows.len());
    }
    println!("# done.");
    // CI floor: every clustered run must reproduce its in-process
    // reference bit-for-bit — a fast-but-wrong cluster path fails here.
    assert!(rows.iter().all(|r| r.bit_identical), "cluster result diverged from in-process");
    assert!(rows.iter().all(|r| r.rel_residual.is_finite()));
}
