//! Parallel-scaling bench: `bak_par` / `kaczmarz_par` / multi-RHS
//! `solve_bak_multi_par` against their serial counterparts across thread
//! counts, on dense and sparse storage.
//!
//! This is also the CI perf-trajectory producer: `--out FILE` writes every
//! measured row as a JSON array (solver, obs, vars, threads, seconds,
//! rel_residual, sweeps, and a downsampled per-sweep residual
//! `trajectory`) — the `bench-smoke` job runs it with
//! `--smoke --out BENCH_PR3.json` and uploads the artifact on every PR.
//!
//! Run: `cargo bench --bench parallel_scaling [-- --smoke] [--samples N]
//!       [--out FILE]`

use solvebak::bench::harness::{downsample_history, TRAJECTORY_CAP};
use solvebak::bench::workload::{SparseWorkload, Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::parallel;
use solvebak::util::alloc::peak_rss_bytes;
use solvebak::solver::{self, SolveOptions};
use solvebak::util::json::{Json, ObjBuilder};
use solvebak::util::rng::Rng;
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

struct Row {
    solver: &'static str,
    obs: usize,
    vars: usize,
    threads: usize,
    seconds: f64,
    rel_residual: f64,
    sweeps: usize,
    /// `VmHWM` after the measurement (`None` where unavailable) — a
    /// process-wide high-water mark, monotone across rows within one run.
    peak_rss_bytes: Option<u64>,
    /// Downsampled `(sweep, residual_norm)` convergence curve of the
    /// probe run, so the uploaded artifact shows not just how fast each
    /// solver finished but how its residual got there.
    trajectory: Vec<(usize, f64)>,
}

impl Row {
    fn to_json(&self) -> Json {
        let traj = Json::Arr(
            self.trajectory
                .iter()
                .map(|&(s, r)| Json::Arr(vec![Json::Num(s as f64), Json::Num(r)]))
                .collect(),
        );
        let mut b = ObjBuilder::new()
            .str("solver", self.solver)
            .num("obs", self.obs as f64)
            .num("vars", self.vars as f64)
            .num("threads", self.threads as f64)
            .num("seconds", self.seconds)
            .num("rel_residual", self.rel_residual)
            .num("sweeps", self.sweeps as f64);
        // Omitted (not zero) where the RSS metric is unavailable.
        if let Some(rss) = self.peak_rss_bytes {
            b = b.num("peak_rss_bytes", rss as f64);
        }
        b.val("trajectory", traj).build()
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    let out_path = args.get("out").map(str::to_string);

    // Thread axis: capped at what the box has in smoke mode so CI numbers
    // measure real concurrency, not oversubscription noise.
    let hw = parallel::default_threads();
    let thread_axis: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&t| !smoke || t <= hw.max(2)).collect();
    let (obs, vars) = if smoke { (4_000, 128) } else { (40_000, 512) };
    let sweeps = if smoke { 4 } else { 8 };
    let nrhs = if smoke { 4 } else { 16 };

    let mut opts = SolveOptions::default();
    opts.max_sweeps = sweeps;
    opts.tol = 0.0;

    println!("# parallel scaling — {obs}x{vars}, {sweeps} sweeps, threads {thread_axis:?}");
    println!(
        "{:<22} {:>8} | {:>10} {:>9} {:>12}",
        "solver", "threads", "time_ms", "speedup", "rel_resid"
    );
    let mut rows: Vec<Row> = Vec::new();

    // Dense workload shared by the whole matrix of measurements.
    let w = Workload::consistent(WorkloadSpec::new(obs, vars, 42));
    let sw = SparseWorkload::uniform(WorkloadSpec::new(obs, vars, 43), 0.01);

    let mut serial_ms = 0.0f64;
    for &t in &thread_axis {
        opts.threads = t;
        let rep = parallel::solve_bak_par(&w.x, &w.y, &opts);
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(parallel::solve_bak_par(&w.x, &w.y, &opts));
        }));
        let ms = tm.min * 1e3;
        if t == 1 {
            serial_ms = ms;
        }
        println!(
            "{:<22} {:>8} | {:>10.2} {:>8.2}x {:>12.3e}",
            "bak_par(dense)", t, ms, serial_ms / ms, rep.rel_residual()
        );
        rows.push(Row {
            solver: "bak_par",
            obs,
            vars,
            threads: t,
            seconds: tm.min,
            rel_residual: rep.rel_residual(),
            sweeps: rep.sweeps,
            peak_rss_bytes: peak_rss_bytes(),
            trajectory: downsample_history(
                &rep.history, opts.check_every, rep.sweeps, TRAJECTORY_CAP,
            ),
        });
    }

    let mut serial_ms = 0.0f64;
    for &t in &thread_axis {
        opts.threads = t;
        let rep = parallel::solve_bak_par_csc(&sw.x, &sw.y, &opts);
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(parallel::solve_bak_par_csc(&sw.x, &sw.y, &opts));
        }));
        let ms = tm.min * 1e3;
        if t == 1 {
            serial_ms = ms;
        }
        println!(
            "{:<22} {:>8} | {:>10.2} {:>8.2}x {:>12.3e}",
            "bak_par(csc d=0.01)", t, ms, serial_ms / ms, rep.rel_residual()
        );
        rows.push(Row {
            solver: "bak_par_csc",
            obs,
            vars,
            threads: t,
            seconds: tm.min,
            rel_residual: rep.rel_residual(),
            sweeps: rep.sweeps,
            peak_rss_bytes: peak_rss_bytes(),
            trajectory: downsample_history(
                &rep.history, opts.check_every, rep.sweeps, TRAJECTORY_CAP,
            ),
        });
    }

    let mut serial_ms = 0.0f64;
    for &t in &thread_axis {
        opts.threads = t;
        let rep = parallel::solve_kaczmarz_par(&w.x, &w.y, &opts);
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(parallel::solve_kaczmarz_par(&w.x, &w.y, &opts));
        }));
        let ms = tm.min * 1e3;
        if t == 1 {
            serial_ms = ms;
        }
        println!(
            "{:<22} {:>8} | {:>10.2} {:>8.2}x {:>12.3e}",
            "kaczmarz_par(dense)", t, ms, serial_ms / ms, rep.rel_residual()
        );
        rows.push(Row {
            solver: "kaczmarz_par",
            obs,
            vars,
            threads: t,
            seconds: tm.min,
            rel_residual: rep.rel_residual(),
            sweeps: rep.sweeps,
            peak_rss_bytes: peak_rss_bytes(),
            trajectory: downsample_history(
                &rep.history, opts.check_every, rep.sweeps, TRAJECTORY_CAP,
            ),
        });
    }

    // Multi-RHS amortisation: one matrix walk, nrhs systems, vs nrhs
    // independent serial solves.
    let mut rng = Rng::seed(44);
    let ys: Vec<Vec<f32>> = (0..nrhs)
        .map(|_| {
            let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
            w.x.matvec(&a)
        })
        .collect();
    opts.threads = 1;
    let t_individual = Summary::of(&sample(&cfg, || {
        for y in &ys {
            std::hint::black_box(solver::solve_bak(&w.x, y, &opts));
        }
    }));
    println!(
        "{:<22} {:>8} | {:>10.2} {:>8} {:>12}",
        format!("bak x{nrhs}(individual)"), 1, t_individual.min * 1e3, "-", "-"
    );
    for &t in &thread_axis {
        opts.threads = t;
        let reps = parallel::solve_bak_multi_par(&w.x, &ys, &opts);
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(parallel::solve_bak_multi_par(&w.x, &ys, &opts));
        }));
        let ms = tm.min * 1e3;
        let worst = reps.iter().map(|r| r.rel_residual()).fold(0.0f64, f64::max);
        println!(
            "{:<22} {:>8} | {:>10.2} {:>8.2}x {:>12.3e}",
            format!("bak_multi_par x{nrhs}"), t, ms, t_individual.min * 1e3 / ms, worst
        );
        rows.push(Row {
            solver: "bak_multi_par",
            obs,
            vars,
            threads: t,
            seconds: tm.min,
            rel_residual: worst,
            sweeps: reps.iter().map(|r| r.sweeps).max().unwrap_or(0),
            peak_rss_bytes: peak_rss_bytes(),
            // First member's curve — all members share the matrix walk.
            trajectory: reps
                .first()
                .map(|r| downsample_history(&r.history, opts.check_every, r.sweeps, TRAJECTORY_CAP))
                .unwrap_or_default(),
        });
    }

    // Serial reference rows so the JSON trajectory is self-contained.
    let rep = solver::solve_bak(&w.x, &w.y, &opts);
    let tm = Summary::of(&sample(&cfg, || {
        std::hint::black_box(solver::solve_bak(&w.x, &w.y, &opts));
    }));
    rows.push(Row {
        solver: "bak",
        obs,
        vars,
        threads: 1,
        seconds: tm.min,
        rel_residual: rep.rel_residual(),
        sweeps: rep.sweeps,
        peak_rss_bytes: peak_rss_bytes(),
        trajectory: downsample_history(&rep.history, opts.check_every, rep.sweeps, TRAJECTORY_CAP),
    });

    if let Some(path) = out_path {
        let json = Json::Arr(rows.iter().map(Row::to_json).collect());
        std::fs::write(&path, json.to_string()).expect("write bench json");
        println!("# wrote {} rows to {path}", rows.len());
    }
    println!("# done.");
    // Sanity floor so CI catches a broken parallel path, not just a slow
    // one: every measured solve stayed finite.
    assert!(rows.iter().all(|r| r.rel_residual.is_finite()));
}
