//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. thr sweep — convergence + time of BAKP's stale-error blocks as the
//!     block width grows (the paper's §6 caveat, quantified).
//!  B. cyclic vs shuffled column order for SolveBak.
//!  C. tolerance sweep — the paper's "straightforwardly controlled"
//!     accuracy/time trade.
//!  D. CGLS comparison — the textbook iterative comparator the paper
//!     omits (honest context for Table 1).
//!  E. PJRT artifact sweep vs native sweep cost (L3 dispatch overhead).
//!
//! Run: `cargo bench --bench ablations [-- --samples N] [--smoke]`
//!
//! `--smoke` shrinks every workload (~10x per dimension) and drops to one
//! sample — the CI bench-smoke regime.

use solvebak::baselines::cgls_solve;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::linalg::blas1;
use solvebak::solver::{self, ColumnOrder, SolveOptions};
use solvebak::util::alloc::CountingAlloc;
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    // --smoke: ~10x smaller per dimension, CI-sized.
    let scale = if smoke { 0.1 } else { 1.0 };

    ablation_thr(&cfg, scale);
    ablation_order(&cfg, scale);
    ablation_tolerance(&cfg, scale);
    ablation_cgls(&cfg, scale);
    ablation_pjrt(&cfg);
}

/// A: thr sweep on a fixed tall system.
fn ablation_thr(cfg: &BenchConfig, scale: f64) {
    let spec = WorkloadSpec::new(20_000, 512, 11).scaled(scale);
    println!("\n## A. BAKP thr sweep (obs={}, vars={}, tol=1e-6)", spec.obs, spec.vars);
    println!("{:>6} | {:>10} | {:>7} | {:>12}", "thr", "time_ms", "sweeps", "rel_resid");
    let w = Workload::consistent(spec);
    for thr in [1usize, 8, 32, 64, 128, 256, 512] {
        let mut o = SolveOptions::default();
        o.thr = thr;
        o.tol = 1e-6;
        o.max_sweeps = 400;
        let rep = solver::solve_bakp(&w.x, &w.y, &o);
        let t = Summary::of(&sample(cfg, || {
            std::hint::black_box(solver::solve_bakp(&w.x, &w.y, &o));
        }));
        println!(
            "{:>6} | {:>10.2} | {:>7} | {:>12.3e}",
            thr, t.min * 1e3, rep.sweeps, rep.rel_residual()
        );
    }
    println!("# paper §6: converges 'if thr is small with respect to vars'; expect degradation at large thr.");
}

/// B: cyclic vs shuffled order.
fn ablation_order(cfg: &BenchConfig, scale: f64) {
    let spec = WorkloadSpec::new(20_000, 256, 12).scaled(scale);
    println!("\n## B. SolveBak column order (obs={}, vars={})", spec.obs, spec.vars);
    println!("{:>9} | {:>10} | {:>7}", "order", "time_ms", "sweeps");
    let w = Workload::consistent(spec);
    for (name, order) in [("cyclic", ColumnOrder::Cyclic), ("shuffled", ColumnOrder::Shuffled)] {
        let mut o = SolveOptions::default();
        o.order = order;
        o.tol = 1e-6;
        o.max_sweeps = 300;
        let rep = solver::solve_bak(&w.x, &w.y, &o);
        let t = Summary::of(&sample(cfg, || {
            std::hint::black_box(solver::solve_bak(&w.x, &w.y, &o));
        }));
        println!("{:>9} | {:>10.2} | {:>7}", name, t.min * 1e3, rep.sweeps);
    }
}

/// C: tolerance sweep — accuracy vs time.
fn ablation_tolerance(cfg: &BenchConfig, scale: f64) {
    let spec = WorkloadSpec::new(50_000, 256, 13).scaled(scale);
    println!("\n## C. tolerance early-break (obs={}, vars={})", spec.obs, spec.vars);
    println!("{:>9} | {:>10} | {:>7} | {:>12}", "tol", "time_ms", "sweeps", "mape");
    let w = Workload::consistent(spec);
    let truth = w.a_true.clone().unwrap();
    for tol in [1e-2, 1e-3, 1e-4, 1e-5, 1e-6] {
        let mut o = SolveOptions::default();
        o.tol = tol;
        o.max_sweeps = 500;
        let rep = solver::solve_bak(&w.x, &w.y, &o);
        let t = Summary::of(&sample(cfg, || {
            std::hint::black_box(solver::solve_bak(&w.x, &w.y, &o));
        }));
        println!(
            "{:>9.0e} | {:>10.2} | {:>7} | {:>12.3e}",
            tol, t.min * 1e3, rep.sweeps,
            solvebak::util::stats::mape(&rep.a, &truth)
        );
    }
}

/// D: CGLS vs BAK on an increasingly ill-conditioned tall system.
fn ablation_cgls(cfg: &BenchConfig, scale: f64) {
    let spec = WorkloadSpec::new(20_000, 256, 14).scaled(scale);
    println!("\n## D. BAK vs CGLS (textbook comparator), obs={} vars={}", spec.obs, spec.vars);
    println!("{:>12} | {:>10} | {:>7} | {:>12}", "method", "time_ms", "iters", "rel_resid");
    let w = Workload::consistent(spec);
    let mut o = SolveOptions::default();
    o.tol = 1e-6;
    o.max_sweeps = 400;
    let rep = solver::solve_bak(&w.x, &w.y, &o);
    let t_bak = Summary::of(&sample(cfg, || {
        std::hint::black_box(solver::solve_bak(&w.x, &w.y, &o));
    }));
    println!(
        "{:>12} | {:>10.2} | {:>7} | {:>12.3e}",
        "BAK", t_bak.min * 1e3, rep.sweeps, rep.rel_residual()
    );
    let crep = cgls_solve(&w.x, &w.y, 400, 1e-7);
    let rel = (blas1::sum_sq_f64(&solvebak::linalg::residual(&w.x, &w.y, &crep.a))
        / blas1::sum_sq_f64(&w.y))
    .sqrt();
    let t_cgls = Summary::of(&sample(cfg, || {
        std::hint::black_box(cgls_solve(&w.x, &w.y, 400, 1e-7));
    }));
    println!(
        "{:>12} | {:>10.2} | {:>7} | {:>12.3e}",
        "CGLS", t_cgls.min * 1e3, crep.iterations, rel
    );
    println!("# context the paper omits: CG-class methods need O(sqrt(cond)) iterations vs CD's O(cond).");
}

/// E: PJRT sweep dispatch cost vs the native sweep.
fn ablation_pjrt(cfg: &BenchConfig) {
    println!("\n## E. PJRT artifact sweep vs native sweep (256x64 bucket)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("# skipped: no artifacts (run `make artifacts`)");
        return;
    }
    let eng = match solvebak::runtime::Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("# skipped: engine unavailable ({e})");
            return;
        }
    };
    let w = Workload::consistent(WorkloadSpec::new(256, 64, 15));
    let mut o = SolveOptions::default();
    o.max_sweeps = 1;
    o.tol = 0.0;
    o.thr = 32;
    let t_native = Summary::of(&sample(cfg, || {
        std::hint::black_box(solver::solve_bakp(&w.x, &w.y, &o));
    }));
    let t_pjrt = Summary::of(&sample(cfg, || {
        std::hint::black_box(
            eng.solve(&w.x, &w.y, &o, solvebak::runtime::ArtifactKind::BakpSweep).unwrap(),
        );
    }));
    println!(
        "native one-sweep: {:>8.3} ms | pjrt one-sweep: {:>8.3} ms | dispatch overhead {:.1}x",
        t_native.min * 1e3,
        t_pjrt.min * 1e3,
        t_pjrt.min / t_native.min,
    );
    println!("# pjrt includes host<->device copies of a/e per sweep; amortised in multi-sweep solves.");
}
