//! Out-of-core streaming bench: in-memory BAK vs `solve_bak_stream` on the
//! same planted system at three sizes — wall-time, peak RSS (`VmHWM`), and
//! the stream's read/stall counters. The streamed run holds only the
//! prefetch buffer pool resident (`--mem-budget`, default 8 MiB), so the
//! peak-RSS columns show what the out-of-core path buys as the matrix
//! outgrows the budget.
//!
//! This is the CI `stream-smoke` trajectory producer: `--out FILE` writes
//! every row as a JSON array; the job runs
//! `--smoke --out BENCH_PR6.json` and uploads the artifact.
//!
//! Run: `cargo bench --bench streaming_oom [-- --smoke] [--samples N]
//!       [--mem-budget BYTES] [--out FILE]`

use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::solver::{self, SolveOptions};
use solvebak::stream::{
    default_chunk_cols, solve_bak_stream, temp_chunk_path, write_chunked_dense, StreamedMatrix,
};
use solvebak::util::alloc::{mib, peak_rss_bytes};
use solvebak::util::json::{Json, ObjBuilder};
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

struct Row {
    mode: &'static str,
    obs: usize,
    vars: usize,
    seconds: f64,
    rel_residual: f64,
    sweeps: usize,
    peak_rss_bytes: Option<u64>,
    mem_budget: usize,
    chunks_read: u64,
    bytes_read: u64,
    buffer_stalls: u64,
}

impl Row {
    fn to_json(&self) -> Json {
        let mut b = ObjBuilder::new()
            .str("solver", "bak")
            .str("mode", self.mode)
            .num("obs", self.obs as f64)
            .num("vars", self.vars as f64)
            .num("seconds", self.seconds)
            .num("rel_residual", self.rel_residual)
            .num("sweeps", self.sweeps as f64);
        // Omitted (not zero) where the RSS metric is unavailable.
        if let Some(rss) = self.peak_rss_bytes {
            b = b.num("peak_rss_bytes", rss as f64);
        }
        b.num("mem_budget", self.mem_budget as f64)
            .num("stream_chunks_read", self.chunks_read as f64)
            .num("stream_bytes_read", self.bytes_read as f64)
            .num("stream_buffer_stalls", self.buffer_stalls as f64)
            .build()
    }
}

/// Console cell for the RSS column: "123.4", or "n/a" where the metric
/// is unavailable (non-Linux; see `util::alloc::peak_rss_bytes`).
fn fmt_rss_mib(rss: Option<u64>) -> String {
    rss.map_or_else(|| "n/a".to_string(), |b| format!("{:.1}", mib(b)))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    let out_path = args.get("out").map(str::to_string);
    let budget = args.get_usize("mem-budget", 0).expect("mem-budget");

    let shapes: &[(usize, usize)] = if smoke {
        &[(2_000, 64), (4_000, 96), (8_000, 128)]
    } else {
        &[(20_000, 256), (50_000, 384), (100_000, 512)]
    };
    let mut opts = SolveOptions::default();
    opts.max_sweeps = if smoke { 4 } else { 8 };
    opts.tol = 0.0;

    println!("# streaming vs in-memory BAK — {} sweeps, budget {}", opts.max_sweeps,
        if budget == 0 { "default".to_string() } else { format!("{budget} B") });
    println!(
        "{:<14} {:>9} {:>6} | {:>10} {:>12} {:>10} {:>8} {:>7}",
        "mode", "obs", "vars", "time_ms", "rel_resid", "rss_mib", "chunks", "stalls"
    );
    let mut rows: Vec<Row> = Vec::new();

    for &(obs, vars) in shapes {
        let w = Workload::consistent(WorkloadSpec::new(obs, vars, 42));

        // In-memory reference.
        let rep_mem = solver::solve_bak(&w.x, &w.y, &opts);
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(solver::solve_bak(&w.x, &w.y, &opts));
        }));
        let rss = peak_rss_bytes();
        println!(
            "{:<14} {:>9} {:>6} | {:>10.2} {:>12.3e} {:>10} {:>8} {:>7}",
            "in_memory", obs, vars, tm.min * 1e3, rep_mem.rel_residual(), fmt_rss_mib(rss), "-", "-"
        );
        rows.push(Row {
            mode: "in_memory",
            obs,
            vars,
            seconds: tm.min,
            rel_residual: rep_mem.rel_residual(),
            sweeps: rep_mem.sweeps,
            peak_rss_bytes: rss,
            mem_budget: 0,
            chunks_read: 0,
            bytes_read: 0,
            buffer_stalls: 0,
        });

        // Streamed run over the same matrix serialized to a chunked file.
        let path = temp_chunk_path(&format!("bench_{obs}x{vars}"));
        write_chunked_dense(&w.x, default_chunk_cols(obs, vars), &path).expect("write chunked");
        let mut sm = StreamedMatrix::open(&path).expect("open chunked");
        if budget > 0 {
            sm = sm.with_budget(budget);
        }
        let rep_stream = solve_bak_stream(&sm, &w.y, &opts).expect("streamed solve");
        assert_eq!(
            rep_mem.a, rep_stream.report.a,
            "streamed BAK must be bit-identical to in-memory at {obs}x{vars}"
        );
        let tm = Summary::of(&sample(&cfg, || {
            std::hint::black_box(solve_bak_stream(&sm, &w.y, &opts).expect("streamed solve"));
        }));
        let rss = peak_rss_bytes();
        let st = rep_stream.stats;
        println!(
            "{:<14} {:>9} {:>6} | {:>10.2} {:>12.3e} {:>10} {:>8} {:>7}",
            "streamed", obs, vars, tm.min * 1e3,
            rep_stream.report.rel_residual(), fmt_rss_mib(rss), st.chunks_read, st.buffer_stalls
        );
        rows.push(Row {
            mode: "streamed",
            obs,
            vars,
            seconds: tm.min,
            rel_residual: rep_stream.report.rel_residual(),
            sweeps: rep_stream.report.sweeps,
            peak_rss_bytes: rss,
            mem_budget: sm.mem_budget(),
            chunks_read: st.chunks_read,
            bytes_read: st.bytes_read,
            buffer_stalls: st.buffer_stalls,
        });
        let _ = std::fs::remove_file(&path);
    }

    if let Some(path) = out_path {
        let json = Json::Arr(rows.iter().map(Row::to_json).collect());
        std::fs::write(&path, json.to_string()).expect("write bench json");
        println!("# wrote {} rows to {path}", rows.len());
    }
    println!("# done.");
    // Sanity floor for CI: every solve stayed finite and every streamed
    // row actually read chunks from disk.
    assert!(rows.iter().all(|r| r.rel_residual.is_finite() && r.seconds > 0.0));
    assert!(rows
        .iter()
        .filter(|r| r.mode == "streamed")
        .all(|r| r.chunks_read > 0 && r.bytes_read > 0));
}
