//! Table 1 reproduction: time / memory-allocations / MAPE for
//! LAPACK(QR) vs BAK vs BAKP over the paper's 12 (vars, obs) configs.
//!
//! Run: `cargo bench --bench table1 [-- --scale F | --full] [--samples N]`
//!
//! By default each row is shrunk so its matrix fits a CI-friendly element
//! budget (the paper's row 12 is a 40 GB matrix); `--full` runs the
//! published dimensions verbatim — bring RAM and patience. Speedup RATIOS
//! are dimension-driven and survive scaling; that is the "shape" we
//! compare against the paper (see EXPERIMENTS.md).

use solvebak::api::SolverKind;
use solvebak::bench::harness::{run_method, table1_opts};
use solvebak::bench::paper::TABLE1;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::util::alloc::CountingAlloc;
use solvebak::util::timer::BenchConfig;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Element budget for the default (scaled) mode: 2^22 f32 = 16 MiB.
/// Sized so the O(obs*vars^2) QR baseline finishes each row in seconds on
/// a single-core CI box; `--scale`/`--full` override.
const DEFAULT_BUDGET: usize = 1 << 22;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let full = args.flag("full");
    let smoke = args.flag("smoke");
    let forced_scale = args.get_f64("scale", 0.0).expect("scale");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    // --smoke: CI-sized rows (64x smaller element budget, 1 sample).
    let budget = if smoke { 1 << 16 } else { DEFAULT_BUDGET };

    println!("# Table 1 reproduction — LAPACK(QR) vs BAK vs BAKP");
    println!("# paper rows: published numbers; measured: this machine.");
    println!(
        "# mode: {}",
        if full { "FULL paper dims".into() }
        else if forced_scale > 0.0 { format!("scale={forced_scale}") }
        else { format!("auto-scale to {budget} elements") }
    );
    println!(
        "{:<3} {:>9} {:>6} | {:>11} {:>11} {:>11} | {:>9} {:>9} | {:>9} {:>9} | {:>8} {:>8}",
        "#", "obs", "vars",
        "qr_ms", "bak_ms", "bakp_ms",
        "bak_mape", "bakp_mape",
        "memB_MiB", "memP_MiB",
        "spd_msr", "spd_ppr"
    );

    for row in &TABLE1 {
        let spec0 = WorkloadSpec::new(row.obs, row.vars, 42 + row.id as u64);
        let spec = if full {
            spec0
        } else if forced_scale > 0.0 {
            spec0.scaled(forced_scale)
        } else {
            let elems = row.obs * row.vars;
            let f = ((budget as f64) / elems as f64).sqrt().min(1.0);
            spec0.scaled(f)
        };
        let w = Workload::consistent(spec);
        let thr = row.thr.min(spec.vars.max(2) / 2).max(1);
        let threads = solvebak::linalg::blas2::num_threads().min(row.threads);

        let qr = run_method(&w, SolverKind::Qr, &table1_opts(thr, 1), &cfg);
        let bak = run_method(&w, SolverKind::Bak, &table1_opts(thr, 1), &cfg);
        let bakp = run_method(&w, SolverKind::Bakp, &table1_opts(thr, threads), &cfg);
        let (qr, bak, bakp) = match (qr, bak, bakp) {
            (Ok(q), Ok(b), Ok(p)) => (q, b, p),
            (q, b, p) => {
                // A degraded row (e.g. rank-deficient draw) must not abort
                // the remaining rows.
                let err = [q.err(), b.err(), p.err()].into_iter().flatten().next().unwrap();
                println!("{:<3} {:>9} {:>6} | row degraded: {err}", row.id, spec.obs, spec.vars);
                continue;
            }
        };

        let spd_bak = qr.time_ms() / bak.time_ms();
        println!(
            "{:<3} {:>9} {:>6} | {:>11.3} {:>11.3} {:>11.3} | {:>9.2e} {:>9.2e} | {:>9.2} {:>9.2} | {:>8.1} {:>8.1}",
            row.id, spec.obs, spec.vars,
            qr.time_ms(), bak.time_ms(), bakp.time_ms(),
            bak.mape, bakp.mape,
            bak.mem_mib(), bakp.mem_mib(),
            spd_bak, row.speedup_bak(),
        );
        println!(
            "    paper row {:>2}:  lapack {:>10.1}ms  bak {:>9.1}ms  bakp {:>9.1}ms | mem {:>7.1}/{:>6.1}/{:>6.1} MiB | spd {:>6.1}/{:>6.1}",
            row.id, row.time_ms_lapack, row.time_ms_bak, row.time_ms_bakp,
            row.mem_mib_lapack, row.mem_mib_bak, row.mem_mib_bakp,
            row.speedup_bak(), row.speedup_bakp(),
        );
        // The shape check the reproduction stands on: BAK beats QR on
        // every (tall) row, as in the paper.
        let who_wins = if bak.time_ms() < qr.time_ms() { "BAK" } else { "QR" };
        println!(
            "    shape: winner = {who_wins} (paper: BAK) | mem ratio qr/bak = {:.1} (paper {:.1})",
            qr.mem_mib() / bak.mem_mib().max(1e-9), row.mem_excess_bak(),
        );
    }
    println!("# done. Record in EXPERIMENTS.md.");
}
