//! Sparse-vs-dense SolveBak at fixed shape across densities: the
//! acceptance bench for the sparse subsystem. A BAK sweep is one dot +
//! one axpy per column, so on CSC storage the sweep cost drops from
//! O(obs*vars) to O(nnz) — at density d the arithmetic shrinks by ~1/d,
//! and this bench measures how much of that survives the gather/scatter
//! overhead of compressed storage.
//!
//! Shape is the ISSUE's 4096x1024 tall system; both solvers run the same
//! fixed sweep budget (tol = 0) so the comparison is pure per-sweep cost.
//!
//! Run: `cargo bench --bench sparse_speedup [-- --smoke]`

use solvebak::bench::workload::{SparseWorkload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::solver::{self, SolveOptions};
use solvebak::sparse;
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let (obs, vars) = (4096, 1024);
    let sweeps = if smoke { 2 } else { 4 };
    let cfg = BenchConfig {
        warmup: 1,
        samples: if smoke { 1 } else { 5 },
        ..BenchConfig::default()
    };
    let mut opts = SolveOptions::default();
    opts.max_sweeps = sweeps;
    opts.tol = 0.0;

    println!("# sparse vs dense BAK, {obs}x{vars}, {sweeps} sweeps per solve");
    println!(
        "{:>9} {:>10} {:>12} {:>12} {:>9}",
        "density", "nnz", "dense", "sparse", "speedup"
    );

    for density in [0.001, 0.01, 0.05, 0.2] {
        let w = SparseWorkload::uniform(WorkloadSpec::new(obs, vars, 42), density);
        let dense = w.densified();
        let y = &w.y;

        let td = Summary::of(&sample(&cfg, || {
            std::hint::black_box(solver::solve_bak(&dense, y, &opts));
        }));
        let ts = Summary::of(&sample(&cfg, || {
            std::hint::black_box(sparse::solve_bak_csc(&w.x, y, &opts));
        }));

        println!(
            "{:>9.3} {:>10} {:>10.2}ms {:>10.2}ms {:>8.1}x",
            density,
            w.x.nnz(),
            td.min * 1e3,
            ts.min * 1e3,
            td.min / ts.min
        );
        if density <= 0.01 {
            assert!(
                ts.min < td.min,
                "acceptance: native sparse BAK must beat dense at density {density} \
                 (sparse {:.3}ms vs dense {:.3}ms)",
                ts.min * 1e3,
                td.min * 1e3
            );
        }
    }

    // The power-law shape: a few dense head columns, long sparse tail.
    let w = SparseWorkload::power_law(WorkloadSpec::new(obs, vars, 43), 1.0, 0.5);
    let dense = w.densified();
    let y = &w.y;
    let td = Summary::of(&sample(&cfg, || {
        std::hint::black_box(solver::solve_bak(&dense, y, &opts));
    }));
    let ts = Summary::of(&sample(&cfg, || {
        std::hint::black_box(sparse::solve_bak_csc(&w.x, y, &opts));
    }));
    println!(
        "power-law (alpha=1, head 50%): nnz={} dense {:.2}ms sparse {:.2}ms ({:.1}x)",
        w.x.nnz(),
        td.min * 1e3,
        ts.min * 1e3,
        td.min / ts.min
    );
}
