//! Figure 1 reproduction: solution-time speed-up of SolveBak and SolveBakP
//! versus the standard (QR/"BLAS") solver, across the Table-1 configs.
//!
//! Prints the speed-up series plus an ASCII log-scale bar chart — the same
//! information as the paper's Figure 1.
//!
//! Run: `cargo bench --bench figure1_speedup [-- --scale F] [--samples N]`

use solvebak::api::SolverKind;
use solvebak::bench::harness::{run_method, table1_opts};
use solvebak::bench::paper::TABLE1;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::util::alloc::CountingAlloc;
use solvebak::util::timer::BenchConfig;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const DEFAULT_BUDGET: usize = 1 << 21; // speedier than table1: 2M elements

fn bar(v: f64, max: f64) -> String {
    // log-scale bar, 1..max mapped over 48 chars.
    let frac = if v <= 1.0 || max <= 1.0 { 0.0 } else { (v.ln() / max.ln()).clamp(0.0, 1.0) };
    "#".repeat((frac * 48.0).round() as usize)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let samples = args.get_usize("samples", if smoke { 1 } else { 3 }).expect("samples");
    let forced_scale = args.get_f64("scale", 0.0).expect("scale");
    let cfg = BenchConfig { warmup: 1, samples, ..BenchConfig::default() };
    // --smoke: CI-sized rows (32x smaller element budget, 1 sample).
    let budget = if smoke { 1 << 16 } else { DEFAULT_BUDGET };

    println!("# Figure 1 reproduction — speed-up vs standard solver (QR)");
    let mut rows = Vec::new();
    for row in &TABLE1 {
        let spec0 = WorkloadSpec::new(row.obs, row.vars, 7 + row.id as u64);
        let spec = if forced_scale > 0.0 {
            spec0.scaled(forced_scale)
        } else {
            let f = ((budget as f64) / (row.obs * row.vars) as f64).sqrt().min(1.0);
            spec0.scaled(f)
        };
        let w = Workload::consistent(spec);
        let thr = row.thr.min(spec.vars.max(2) / 2).max(1);
        let threads = solvebak::linalg::blas2::num_threads().min(row.threads);
        let qr = run_method(&w, SolverKind::Qr, &table1_opts(thr, 1), &cfg);
        let bak = run_method(&w, SolverKind::Bak, &table1_opts(thr, 1), &cfg);
        let bakp = run_method(&w, SolverKind::Bakp, &table1_opts(thr, threads), &cfg);
        let (qr, bak, bakp) = match (qr, bak, bakp) {
            (Ok(q), Ok(b), Ok(p)) => (q, b, p),
            (q, b, p) => {
                let err = [q.err(), b.err(), p.err()].into_iter().flatten().next().unwrap();
                println!("row {}: degraded ({err}); skipping", row.id);
                continue;
            }
        };
        rows.push((row, spec, qr.time_ms() / bak.time_ms(), qr.time_ms() / bakp.time_ms()));
    }

    let max_s = rows
        .iter()
        .flat_map(|(r, _, b, p)| [*b, *p, r.speedup_bak(), r.speedup_bakp()])
        .fold(1.0f64, f64::max);

    println!("\n## BAK speed-up (measured M vs paper P)");
    for (row, spec, sb, _) in &rows {
        println!(
            "{:>2} {:>9}x{:<5} M {:>8.1} |{}",
            row.id, spec.obs, spec.vars, sb, bar(*sb, max_s)
        );
        println!(
            "   {:>9}x{:<5} P {:>8.1} |{}",
            row.obs, row.vars, row.speedup_bak(), bar(row.speedup_bak(), max_s)
        );
    }
    println!("\n## BAKP speed-up (measured M vs paper P)");
    for (row, spec, _, sp) in &rows {
        println!(
            "{:>2} {:>9}x{:<5} M {:>8.1} |{}",
            row.id, spec.obs, spec.vars, sp, bar(*sp, max_s)
        );
        println!(
            "   {:>9}x{:<5} P {:>8.1} |{}",
            row.obs, row.vars, row.speedup_bakp(), bar(row.speedup_bakp(), max_s)
        );
    }

    // Shape summary: tall rows must favour the BAK family.
    let won: usize = rows.iter().filter(|(_, _, sb, _)| *sb > 1.0).count();
    println!("\n# BAK faster than QR on {won}/{} rows (paper: 12/12 published rows)", rows.len());
}
