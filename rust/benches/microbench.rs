//! Hot-path microbenchmarks for the §Perf pass: BLAS-1 dot/axpy (the
//! Algorithm-1 inner step), the fused cd_step, one full SolveBak sweep,
//! and gemv. Reports effective memory bandwidth — the roofline for
//! coordinate descent is the memory stream, not FLOPs.
//!
//! Run: `cargo bench --bench microbench [-- --smoke]`

use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::cli::Args;
use solvebak::linalg::{blas1, blas2};
use solvebak::solver::{self, SolveOptions};
use solvebak::util::rng::Rng;
use solvebak::util::stats::Summary;
use solvebak::util::timer::{sample, BenchConfig};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let smoke = args.flag("smoke");
    let cfg = if smoke {
        BenchConfig { warmup: 1, samples: 2, ..BenchConfig::default() }
    } else {
        BenchConfig { warmup: 2, samples: 7, ..BenchConfig::default() }
    };
    // Full: 1M f32 = 4 MiB per vector (out of L2, streaming); smoke: 64K.
    let n = if smoke { 1 << 16 } else { 1 << 20 };
    let mut rng = Rng::seed(1);
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let mut y: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

    println!("# hot-path microbenchmarks (n = {n} f32)");

    // dot: streams 2 vectors (8 bytes/elem).
    let t = Summary::of(&sample(&cfg, || {
        std::hint::black_box(blas1::dot(&x, &y));
    }));
    println!(
        "dot      : {:>8.3} ms  -> {:>6.1} GB/s",
        t.min * 1e3,
        (8 * n) as f64 / t.min / 1e9
    );

    // axpy: streams 2 reads + 1 write (12 bytes/elem).
    let t = Summary::of(&sample(&cfg, || {
        blas1::axpy(std::hint::black_box(1.000001f32), &x, &mut y);
    }));
    println!(
        "axpy     : {:>8.3} ms  -> {:>6.1} GB/s",
        t.min * 1e3,
        (12 * n) as f64 / t.min / 1e9
    );

    // cd_step: dot + axpy back-to-back (20 bytes/elem).
    let t = Summary::of(&sample(&cfg, || {
        std::hint::black_box(blas1::cd_step(&x, &mut y, 1e-9));
    }));
    println!(
        "cd_step  : {:>8.3} ms  -> {:>6.1} GB/s",
        t.min * 1e3,
        (20 * n) as f64 / t.min / 1e9
    );

    // One full SolveBak sweep on a Table-1-like tall system.
    let spec = WorkloadSpec::new(50_000, 200, 2).scaled(if smoke { 0.1 } else { 1.0 });
    let w = Workload::consistent(spec);
    let mut o = SolveOptions::default();
    o.max_sweeps = 1;
    o.tol = 0.0;
    let t = Summary::of(&sample(&cfg, || {
        std::hint::black_box(solver::solve_bak(&w.x, &w.y, &o));
    }));
    let bytes = (w.spec.obs * w.spec.vars * 4 * 2 + w.spec.obs * 4) as f64; // x read twice + e
    println!(
        "bak sweep: {:>8.3} ms  -> {:>6.1} GB/s  ({}x{}, dot+axpy per col)",
        t.min * 1e3,
        bytes / t.min / 1e9,
        w.spec.obs,
        w.spec.vars
    );

    // gemv on the same matrix.
    let a: Vec<f32> = (0..w.spec.vars).map(|j| j as f32 * 0.01).collect();
    let t = Summary::of(&sample(&cfg, || {
        std::hint::black_box(blas2::gemv(&w.x, &a));
    }));
    println!(
        "gemv     : {:>8.3} ms  -> {:>6.1} GB/s",
        t.min * 1e3,
        (w.spec.obs * w.spec.vars * 4) as f64 / t.min / 1e9
    );

    // gemv_t (the SolveBakF scoring pass).
    let t = Summary::of(&sample(&cfg, || {
        std::hint::black_box(blas2::gemv_t(&w.x, &w.y));
    }));
    println!(
        "gemv_t   : {:>8.3} ms  -> {:>6.1} GB/s",
        t.min * 1e3,
        (w.spec.obs * w.spec.vars * 4) as f64 / t.min / 1e9
    );
}
