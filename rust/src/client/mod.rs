//! Client-side robustness for the TCP protocol: one-line JSON roundtrips
//! wrapped in a budget-capped, jittered exponential-backoff retry loop.
//!
//! [`Client`] opens a fresh connection per request (the server is
//! connection-per-thread; reconnecting is also what makes connect-level
//! failures retryable) and retries on transport errors — refused, reset,
//! mid-reply EOF — and on structured `error_kind: "overloaded"` replies,
//! where the server's `retry_after_ms` hint becomes the backoff floor.
//! Retried requests carry `"attempt": n` so the server's
//! `retries_attempted` counter sees them (see `PROTOCOL.md`).
//!
//! Backoff is *full-jitter* exponential: retry `n` sleeps a uniform draw
//! from `[cap/2, cap]` with `cap = min(base_ms * 2^(n-1), max_backoff_ms)`
//! raised to any server floor. The jitter source is the repo's
//! deterministic [`Rng`], seeded per client, so tests are reproducible
//! while distinct clients still decorrelate. A wall-clock `budget_ms`
//! bounds the whole loop: a retry that cannot finish its sleep inside the
//! budget is not attempted, and the last reply or error is surfaced.

use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Knobs for [`Client`]'s retry loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast).
    pub max_retries: u32,
    /// Backoff cap for the first retry; doubles per retry.
    pub base_ms: u64,
    /// Ceiling on any single backoff sleep.
    pub max_backoff_ms: u64,
    /// Wall-clock budget for the whole request including sleeps.
    pub budget_ms: u64,
    /// Seed for the jitter stream (vary per client to decorrelate).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_ms: 50,
            max_backoff_ms: 2_000,
            budget_ms: 10_000,
            jitter_seed: 0x9e37_79b9,
        }
    }
}

impl RetryPolicy {
    /// Fail-fast policy: one attempt, no sleeps.
    pub fn none() -> Self {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// The sleep before retry `n` (1-based): full jitter over
    /// `[cap/2, cap]` where `cap = min(base * 2^(n-1), max_backoff_ms)`,
    /// raised to `floor_ms` when the server sent a `retry_after_ms` hint.
    pub fn backoff_ms(&self, retry: u32, floor_ms: u64, rng: &mut Rng) -> u64 {
        let pow = retry.saturating_sub(1).min(32);
        let exp = self.base_ms.saturating_mul(1u64 << pow);
        let cap = exp.min(self.max_backoff_ms).max(floor_ms).max(1);
        let half = (cap / 2).max(1);
        half + rng.next_u64() % (cap - half + 1)
    }
}

/// What ultimately stopped a [`Client::request`] loop.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure that was not retryable (or exhausted the policy).
    Io(io::Error),
    /// The server answered with something that is not one JSON line.
    BadReply(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "transport: {e}"),
            ClientError::BadReply(m) => write!(f, "bad reply: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// A retrying newline-JSON client for the coordinator's TCP server.
pub struct Client {
    addr: String,
    policy: RetryPolicy,
    rng: Rng,
    retries_attempted: u64,
}

impl Client {
    /// Client for `addr` (`host:port`) with the default [`RetryPolicy`].
    pub fn connect(addr: impl Into<String>) -> Self {
        Self::with_policy(addr, RetryPolicy::default())
    }

    /// Client for `addr` with an explicit policy.
    pub fn with_policy(addr: impl Into<String>, policy: RetryPolicy) -> Self {
        Client {
            addr: addr.into(),
            rng: Rng::seed(policy.jitter_seed),
            policy,
            retries_attempted: 0,
        }
    }

    /// Retries this client has performed across all requests (mirrors the
    /// server-side `retries_attempted` counter from this client's view).
    pub fn retries_attempted(&self) -> u64 {
        self.retries_attempted
    }

    /// Send `req` as one JSON line and return the server's one-line JSON
    /// reply, retrying per the policy. Structured non-`overloaded` errors
    /// (bad input, deadline exceeded, ...) are *successful* roundtrips —
    /// the caller branches on `error_kind` — and are never retried. An
    /// `overloaded` reply that outlives the retry budget is returned
    /// as-is so the caller still sees `retry_after_ms`.
    pub fn request(&mut self, req: &Json) -> Result<Json, ClientError> {
        let start = Instant::now();
        let mut attempt: u32 = 0;
        loop {
            let mut wire = req.clone();
            if attempt > 0 {
                if let Json::Obj(fields) = &mut wire {
                    fields.insert("attempt".into(), Json::Num(attempt as f64));
                }
            }
            match self.roundtrip_once(&wire) {
                Ok(reply) => {
                    let overloaded =
                        reply.get("error_kind").and_then(Json::as_str) == Some("overloaded");
                    if !overloaded {
                        return Ok(reply);
                    }
                    let floor = reply
                        .get("retry_after_ms")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64;
                    if !self.sleep_before_retry(&mut attempt, floor, start, "overloaded") {
                        return Ok(reply);
                    }
                }
                Err(RoundtripError::Io(e)) if retryable(&e) => {
                    let why = e.to_string();
                    if !self.sleep_before_retry(&mut attempt, 0, start, &why) {
                        return Err(ClientError::Io(e));
                    }
                }
                Err(RoundtripError::Io(e)) => return Err(ClientError::Io(e)),
                Err(RoundtripError::BadReply(m)) => return Err(ClientError::BadReply(m)),
            }
        }
    }

    /// True when another retry fits the policy and budget (and the
    /// backoff sleep has already happened); false to give up.
    fn sleep_before_retry(
        &mut self,
        attempt: &mut u32,
        floor_ms: u64,
        start: Instant,
        why: &str,
    ) -> bool {
        if *attempt >= self.policy.max_retries {
            return false;
        }
        *attempt += 1;
        let wait = self.policy.backoff_ms(*attempt, floor_ms, &mut self.rng);
        let elapsed = start.elapsed().as_millis() as u64;
        if elapsed.saturating_add(wait) > self.policy.budget_ms {
            return false;
        }
        self.retries_attempted += 1;
        crate::debug!("client", "retry #{attempt} in {wait}ms after: {why}");
        std::thread::sleep(Duration::from_millis(wait));
        true
    }

    fn roundtrip_once(&self, req: &Json) -> Result<Json, RoundtripError> {
        let mut stream = TcpStream::connect(&self.addr).map_err(RoundtripError::Io)?;
        let mut line = req.to_string();
        line.push('\n');
        stream.write_all(line.as_bytes()).map_err(RoundtripError::Io)?;
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).map_err(RoundtripError::Io)?;
        if n == 0 {
            // Server dropped the connection before answering: retryable.
            return Err(RoundtripError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed before reply",
            )));
        }
        Json::parse(reply.trim()).map_err(|e| RoundtripError::BadReply(format!("{e}")))
    }
}

enum RoundtripError {
    Io(io::Error),
    BadReply(String),
}

fn retryable(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::ConnectionRefused
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::TimedOut
            | io::ErrorKind::WouldBlock
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::{Arc, Mutex};

    /// One-reply-per-connection fake server; records each request line.
    fn fake_server(replies: Vec<String>) -> (std::net::SocketAddr, Arc<Mutex<Vec<String>>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        std::thread::spawn(move || {
            for reply in replies {
                let (mut s, _) = match listener.accept() {
                    Ok(c) => c,
                    Err(_) => return,
                };
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                let _ = r.read_line(&mut line);
                seen2.lock().unwrap().push(line.trim().to_string());
                let _ = s.write_all(reply.as_bytes());
                let _ = s.write_all(b"\n");
            }
        });
        (addr, seen)
    }

    fn fast_policy(max_retries: u32) -> RetryPolicy {
        RetryPolicy {
            max_retries,
            base_ms: 1,
            max_backoff_ms: 4,
            budget_ms: 5_000,
            jitter_seed: 7,
        }
    }

    #[test]
    fn ok_reply_needs_no_retry() {
        let (addr, seen) = fake_server(vec![r#"{"ok": true, "pong": "pong"}"#.into()]);
        let mut c = Client::with_policy(addr.to_string(), fast_policy(3));
        let req = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        let reply = c.request(&req).unwrap();
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(c.retries_attempted(), 0);
        assert!(!seen.lock().unwrap()[0].contains("attempt"));
    }

    #[test]
    fn overloaded_reply_is_retried_with_attempt_field() {
        let (addr, seen) = fake_server(vec![
            r#"{"ok": false, "error_kind": "overloaded", "retry_after_ms": 1}"#.into(),
            r#"{"ok": true, "id": 1}"#.into(),
        ]);
        let mut c = Client::with_policy(addr.to_string(), fast_policy(3));
        let req = Json::parse(r#"{"id": 1, "obs": 1, "vars": 1, "x": [1], "y": [1]}"#).unwrap();
        let reply = c.request(&req).unwrap();
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply:?}");
        assert_eq!(c.retries_attempted(), 1);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2);
        assert!(!seen[0].contains("attempt"));
        assert!(seen[1].contains("\"attempt\""), "{}", seen[1]);
    }

    #[test]
    fn exhausted_retries_surface_the_overloaded_reply() {
        let over = r#"{"ok": false, "error_kind": "overloaded", "retry_after_ms": 1}"#;
        let (addr, _) = fake_server(vec![over.into(), over.into()]);
        let mut c = Client::with_policy(addr.to_string(), fast_policy(1));
        let req = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        let reply = c.request(&req).unwrap();
        // The caller still gets the structured overload, hint included.
        assert_eq!(reply.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(reply.get("retry_after_ms").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.retries_attempted(), 1);
    }

    #[test]
    fn non_overloaded_errors_are_not_retried() {
        let (addr, seen) = fake_server(vec![
            r#"{"ok": false, "error_kind": "invalid_input", "error": "missing obs"}"#.into(),
            r#"{"ok": true}"#.into(),
        ]);
        let mut c = Client::with_policy(addr.to_string(), fast_policy(3));
        let req = Json::parse(r#"{"id": 1}"#).unwrap();
        let reply = c.request(&req).unwrap();
        assert_eq!(reply.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        assert_eq!(c.retries_attempted(), 0);
        assert_eq!(seen.lock().unwrap().len(), 1);
    }

    #[test]
    fn connect_failure_retries_then_errors() {
        // Bind-then-drop: the port is (almost certainly) refusing now.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let mut c = Client::with_policy(addr.to_string(), fast_policy(2));
        let req = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        match c.request(&req) {
            Err(ClientError::Io(_)) => {}
            other => panic!("want Io error, got {other:?}"),
        }
        assert_eq!(c.retries_attempted(), 2);
    }

    #[test]
    fn zero_budget_fails_fast_without_sleeping() {
        let over = r#"{"ok": false, "error_kind": "overloaded", "retry_after_ms": 500}"#;
        let (addr, _) = fake_server(vec![over.into()]);
        let mut c = Client::with_policy(
            addr.to_string(),
            RetryPolicy { budget_ms: 0, ..fast_policy(5) },
        );
        let req = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        let t0 = Instant::now();
        let reply = c.request(&req).unwrap();
        assert_eq!(reply.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert!(t0.elapsed() < Duration::from_millis(400), "budget must gate the sleep");
        assert_eq!(c.retries_attempted(), 0);
    }

    #[test]
    fn backoff_doubles_caps_and_respects_server_floor() {
        let p = RetryPolicy {
            max_retries: 10,
            base_ms: 50,
            max_backoff_ms: 300,
            budget_ms: 10_000,
            jitter_seed: 1,
        };
        let mut rng = Rng::seed(1);
        for retry in 1..=8u32 {
            let cap = (50u64 << (retry - 1)).min(300);
            let w = p.backoff_ms(retry, 0, &mut rng);
            assert!(w >= cap / 2 && w <= cap, "retry {retry}: {w} not in [{}, {cap}]", cap / 2);
        }
        // The server's hint raises the floor past the exponential cap.
        let w = p.backoff_ms(1, 900, &mut rng);
        assert!(w >= 450 && w <= 900, "{w}");
    }

    #[test]
    fn backoff_sequence_is_pinned_to_the_documented_spec() {
        // Exact pin of the backoff algorithm against PROTOCOL.md's spec:
        // retry n consumes exactly one `next_u64` and sleeps
        // `half + draw % (cap - half + 1)` with `half = cap/2` and
        // `cap = min(base * 2^(n-1), max_backoff_ms)` raised to any
        // server floor. `spec` is an independent generator stepped in
        // lockstep, so any change to the formula, the draw count, or
        // the jitter window breaks the equality below.
        let p = RetryPolicy {
            max_retries: 10,
            base_ms: 50,
            max_backoff_ms: 2_000,
            budget_ms: 60_000,
            jitter_seed: RetryPolicy::default().jitter_seed,
        };
        let mut live = Rng::seed(p.jitter_seed);
        let mut spec = Rng::seed(p.jitter_seed);
        let caps: [u64; 8] = [50, 100, 200, 400, 800, 1600, 2_000, 2_000];
        for (i, &cap) in caps.iter().enumerate() {
            let half = cap / 2;
            let want = half + spec.next_u64() % (cap - half + 1);
            let got = p.backoff_ms(i as u32 + 1, 0, &mut live);
            assert_eq!(got, want, "retry {}: sequence diverged from spec", i + 1);
        }
        // A retry_after_ms hint above both the exponential cap and the
        // ceiling raises the whole window: sleep lands in [1500, 3000].
        let want = 1_500 + spec.next_u64() % 1_501;
        assert_eq!(p.backoff_ms(1, 3_000, &mut live), want);
        // A hint below the current cap is a no-op on the window.
        let want = 400 + spec.next_u64() % 401;
        assert_eq!(p.backoff_ms(5, 30, &mut live), want);
        // Deep retries clamp the shift (no overflow) at the ceiling.
        let want = 1_000 + spec.next_u64() % 1_001;
        assert_eq!(p.backoff_ms(64, 0, &mut live), want);
    }

    #[test]
    fn backoff_never_sleeps_zero() {
        // Degenerate policies still yield a >= 1ms sleep so the retry
        // loop cannot spin.
        let p = RetryPolicy {
            max_retries: 1,
            base_ms: 0,
            max_backoff_ms: 0,
            budget_ms: 1_000,
            jitter_seed: 3,
        };
        let mut rng = Rng::seed(3);
        for retry in 1..=4 {
            assert_eq!(p.backoff_ms(retry, 0, &mut rng), 1);
        }
    }

    #[test]
    fn roundtrips_against_the_real_server() {
        let coord = Arc::new(crate::coordinator::Coordinator::start(
            crate::coordinator::CoordinatorConfig {
                workers: 1,
                ..crate::coordinator::CoordinatorConfig::default()
            },
        ));
        let server = crate::coordinator::server::Server::bind(coord, 0).unwrap();
        let mut c = Client::with_policy(server.addr().to_string(), fast_policy(2));
        let req = Json::parse(
            r#"{"v": 1, "id": 9, "backend": "qr", "obs": 2, "vars": 2,
                "x": [1,0, 0,1], "y": [4, 5]}"#,
        )
        .unwrap();
        let reply = c.request(&req).unwrap();
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply:?}");
        let a = reply.get("a").unwrap().items();
        assert!((a[0].as_f64().unwrap() - 4.0).abs() < 1e-4);
        server.stop();
    }
}
