//! Measurement runner shared by the bench binaries: times a solver on a
//! workload with warmup + samples, tracks allocations (when the bench
//! binary installs [`crate::util::alloc::CountingAlloc`]) and computes the
//! paper's accuracy metric.
//!
//! Method selection is registry-driven: any [`SolverKind`] measures
//! through the shared [`crate::api::Solver`] trait, and failures (e.g. a
//! rank-deficient workload on the QR baseline) surface as typed
//! [`SolverError`]s so one bad row degrades instead of aborting the run.
//!
//! Timing semantics: the timed quantity is the full trait `solve`,
//! which for direct methods includes the report's `O(obs*vars)`
//! residual computation (iterative solvers maintain it inherently).
//! That keeps the measured work uniform across kinds; relative to the
//! `O(obs*vars^2)` factorization it is a <= 1/vars overhead on the QR
//! column (< 1% at the paper's vars >= 100).

use crate::api::{solver_for, Problem, SolverError, SolverKind};
use crate::solver::SolveOptions;
use crate::util::alloc;
use crate::util::stats::{mape, Summary};
use crate::util::timer::{sample, BenchConfig};

use super::workload::Workload;

/// Human label for a measured (kind, options) pair, matching the paper's
/// column names for the Table-1 trio.
pub fn method_label(kind: SolverKind, opts: &SolveOptions) -> String {
    match kind {
        SolverKind::Qr => "LAPACK(QR)".into(),
        SolverKind::Bak => "BAK".into(),
        SolverKind::Bakp => format!("BAKP(thr={},t={})", opts.thr, opts.threads),
        k => k.as_str().to_ascii_uppercase(),
    }
}

/// One measured method on one workload.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method_label: String,
    pub time: Summary,
    /// Bytes allocated by ONE run (0 unless the counting allocator is the
    /// binary's global allocator).
    pub alloc_bytes: u64,
    /// Process peak RSS (`VmHWM`) after the measurement, in bytes — 0 on
    /// non-Linux platforms ([`alloc::peak_rss_bytes`]). A high-water mark,
    /// so it reflects the largest method measured so far in the process.
    pub peak_rss_bytes: u64,
    /// MAPE of the solution against the planted coefficients.
    pub mape: f64,
}

impl MethodResult {
    pub fn time_ms(&self) -> f64 {
        self.time.min * 1e3 // @btime semantics: minimum over samples
    }

    pub fn mem_mib(&self) -> f64 {
        alloc::mib(self.alloc_bytes)
    }

    pub fn peak_rss_mib(&self) -> f64 {
        alloc::mib(self.peak_rss_bytes)
    }
}

/// Solver options used for Table-1 measurements: tolerance chosen to land
/// in the paper's MAPE regime.
pub fn table1_opts(thr: usize, threads: usize) -> SolveOptions {
    SolveOptions::builder()
        .max_sweeps(200)
        .tol(1e-6)
        .thr(thr)
        .threads(threads)
        .check_every(1)
        .build()
}

/// Run one solver kind on one workload, honouring the passed options for
/// every kind.
pub fn run_method(
    w: &Workload,
    kind: SolverKind,
    opts: &SolveOptions,
    cfg: &BenchConfig,
) -> Result<MethodResult, SolverError> {
    let solver = solver_for(kind).ok_or_else(|| SolverError::Unavailable {
        backend: kind.to_string(),
        reason: "routing pseudo-kind; measure a concrete registry kind".into(),
    })?;
    let problem = Problem::new(&w.x, &w.y)?;

    // Allocation measurement doubles as the failure probe: if the solver
    // cannot handle this workload, report that instead of timing it.
    let (first, snap) = alloc::measure(|| solver.solve(&problem, opts));
    let a_hat = first?.a;
    let acc = w.a_true.as_ref().map(|t| mape(&a_hat, t)).unwrap_or(f64::NAN);

    // Timing loop.
    let times = sample(cfg, || {
        let _ = std::hint::black_box(solver.solve(&problem, opts));
    });

    Ok(MethodResult {
        method_label: method_label(kind, opts),
        time: Summary::of(&times),
        alloc_bytes: snap.bytes,
        peak_rss_bytes: alloc::peak_rss_bytes(),
        mape: acc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::WorkloadSpec;

    #[test]
    fn run_method_all_backends() {
        let w = Workload::consistent(WorkloadSpec::new(120, 12, 77));
        let cfg = BenchConfig::quick();
        let opts = table1_opts(4, 1);
        for kind in [SolverKind::Qr, SolverKind::Bak, SolverKind::Bakp] {
            let r = run_method(&w, kind, &opts, &cfg).expect("consistent workload");
            assert!(r.time.min > 0.0, "{}", r.method_label);
            assert!(r.mape < 1e-2, "{} mape={}", r.method_label, r.mape);
            if cfg!(target_os = "linux") {
                assert!(r.peak_rss_bytes > 0, "{} VmHWM missing", r.method_label);
            }
        }
    }

    #[test]
    fn run_method_honours_passed_options() {
        // A starved budget (1 sweep, no tolerance) must be visibly less
        // accurate than the Table-1 regime — i.e. cfg is not ignored.
        let w = Workload::consistent(WorkloadSpec::new(200, 30, 78));
        let cfg = BenchConfig::quick();
        let starved = SolveOptions::builder().max_sweeps(1).tol(0.0).build();
        let loose = run_method(&w, SolverKind::Bak, &starved, &cfg).unwrap();
        let tight = run_method(&w, SolverKind::Bak, &table1_opts(50, 1), &cfg).unwrap();
        assert!(
            loose.mape > tight.mape * 10.0,
            "starved {} vs tight {}",
            loose.mape,
            tight.mape
        );
    }

    #[test]
    fn rank_deficient_workload_degrades_gracefully() {
        // Duplicate a column: QR must report the failure, not panic.
        let mut w = Workload::consistent(WorkloadSpec::new(60, 6, 79));
        let c0 = w.x.col(0).to_vec();
        w.x.col_mut(1).copy_from_slice(&c0);
        let r = run_method(&w, SolverKind::Qr, &table1_opts(4, 1), &BenchConfig::quick());
        assert!(matches!(r, Err(SolverError::RankDeficient { .. })), "{r:?}");
    }

    #[test]
    fn auto_kind_is_not_measurable() {
        let w = Workload::consistent(WorkloadSpec::new(30, 3, 80));
        let r = run_method(&w, SolverKind::Auto, &table1_opts(4, 1), &BenchConfig::quick());
        assert!(matches!(r, Err(SolverError::Unavailable { .. })), "{r:?}");
    }

    #[test]
    fn labels_distinct() {
        let o = table1_opts(50, 2);
        assert_ne!(method_label(SolverKind::Qr, &o), method_label(SolverKind::Bak, &o));
        assert!(method_label(SolverKind::Bakp, &o).contains("50"));
        assert_eq!(method_label(SolverKind::Cgls, &o), "CGLS");
    }

    #[test]
    fn table1_opts_paper_regime() {
        let o = table1_opts(50, 4);
        assert_eq!(o.thr, 50);
        assert_eq!(o.threads, 4);
        assert!(o.tol > 0.0);
    }
}
