//! Measurement runner shared by the bench binaries: times a solver on a
//! workload with warmup + samples, tracks allocations (when the bench
//! binary installs [`crate::util::alloc::CountingAlloc`]) and computes the
//! paper's accuracy metric.
//!
//! Method selection is registry-driven: any [`SolverKind`] measures
//! through the shared [`crate::api::Solver`] trait, and failures (e.g. a
//! rank-deficient workload on the QR baseline) surface as typed
//! [`SolverError`]s so one bad row degrades instead of aborting the run.
//!
//! Timing semantics: the timed quantity is the full trait `solve`,
//! which for direct methods includes the report's `O(obs*vars)`
//! residual computation (iterative solvers maintain it inherently).
//! That keeps the measured work uniform across kinds; relative to the
//! `O(obs*vars^2)` factorization it is a <= 1/vars overhead on the QR
//! column (< 1% at the paper's vars >= 100).

use crate::api::{solver_for, Problem, SolverError, SolverKind};
use crate::solver::SolveOptions;
use crate::util::alloc;
use crate::util::stats::{mape, Summary};
use crate::util::timer::{sample, BenchConfig};

use super::workload::Workload;

/// Human label for a measured (kind, options) pair, matching the paper's
/// column names for the Table-1 trio.
pub fn method_label(kind: SolverKind, opts: &SolveOptions) -> String {
    match kind {
        SolverKind::Qr => "LAPACK(QR)".into(),
        SolverKind::Bak => "BAK".into(),
        SolverKind::Bakp => format!("BAKP(thr={},t={})", opts.thr, opts.threads),
        k => k.as_str().to_ascii_uppercase(),
    }
}

/// One measured method on one workload.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method_label: String,
    pub time: Summary,
    /// Bytes allocated by ONE run (0 unless the counting allocator is the
    /// binary's global allocator).
    pub alloc_bytes: u64,
    /// Process peak RSS (`VmHWM`) after the measurement, in bytes — `None`
    /// where the metric is unavailable ([`alloc::peak_rss_bytes`]). A
    /// high-water mark, so it reflects the largest method measured so far
    /// in the process.
    pub peak_rss_bytes: Option<u64>,
    /// MAPE of the solution against the planted coefficients.
    pub mape: f64,
    /// Downsampled convergence trajectory of the probe run (the first,
    /// untimed solve): `(sweep, residual_norm)` pairs, at most
    /// [`TRAJECTORY_CAP`] points, last checkpoint always kept. Direct
    /// methods (QR/Cholesky/Gauss) collapse to the single terminal point.
    pub trajectory: Vec<(usize, f64)>,
}

/// Point cap for [`MethodResult::trajectory`] — small enough to embed in
/// every `BENCH_*.json` row, dense enough to plot a convergence curve.
pub const TRAJECTORY_CAP: usize = 32;

/// Downsample a solver's per-checkpoint squared-residual `history` to at
/// most `cap` `(sweep, residual_norm)` points. Checkpoint `k` happened at
/// sweep `min((k+1)*check_every, total_sweeps)`; the final checkpoint is
/// always kept so the curve ends where the solver stopped.
pub fn downsample_history(
    history: &[f64],
    check_every: usize,
    total_sweeps: usize,
    cap: usize,
) -> Vec<(usize, f64)> {
    if history.is_empty() || cap == 0 {
        return Vec::new();
    }
    let c = check_every.max(1);
    let sweep_of = |k: usize| ((k + 1) * c).min(total_sweeps.max(1));
    let stride = history.len().div_ceil(cap).max(1);
    let mut out: Vec<(usize, f64)> = history
        .iter()
        .enumerate()
        .step_by(stride)
        .map(|(k, &r2)| (sweep_of(k), r2.max(0.0).sqrt()))
        .collect();
    let last = history.len() - 1;
    if out.last().map(|&(s, _)| s) != Some(sweep_of(last)) {
        out.push((sweep_of(last), history[last].max(0.0).sqrt()));
    }
    if out.len() > cap {
        // Drop an interior point, never the endpoint.
        let end = out.pop().unwrap();
        out.truncate(cap - 1);
        out.push(end);
    }
    out
}

impl MethodResult {
    pub fn time_ms(&self) -> f64 {
        self.time.min * 1e3 // @btime semantics: minimum over samples
    }

    pub fn mem_mib(&self) -> f64 {
        alloc::mib(self.alloc_bytes)
    }

    pub fn peak_rss_mib(&self) -> Option<f64> {
        self.peak_rss_bytes.map(alloc::mib)
    }
}

/// Solver options used for Table-1 measurements: tolerance chosen to land
/// in the paper's MAPE regime.
pub fn table1_opts(thr: usize, threads: usize) -> SolveOptions {
    SolveOptions::builder()
        .max_sweeps(200)
        .tol(1e-6)
        .thr(thr)
        .threads(threads)
        .check_every(1)
        .build()
}

/// Run one solver kind on one workload, honouring the passed options for
/// every kind.
pub fn run_method(
    w: &Workload,
    kind: SolverKind,
    opts: &SolveOptions,
    cfg: &BenchConfig,
) -> Result<MethodResult, SolverError> {
    let solver = solver_for(kind).ok_or_else(|| SolverError::Unavailable {
        backend: kind.to_string(),
        reason: "routing pseudo-kind; measure a concrete registry kind".into(),
    })?;
    let problem = Problem::new(&w.x, &w.y)?;

    // Allocation measurement doubles as the failure probe: if the solver
    // cannot handle this workload, report that instead of timing it.
    let (first, snap) = alloc::measure(|| solver.solve(&problem, opts));
    let report = first?;
    let trajectory =
        downsample_history(&report.history, opts.check_every, report.sweeps, TRAJECTORY_CAP);
    let acc = w.a_true.as_ref().map(|t| mape(&report.a, t)).unwrap_or(f64::NAN);

    // Timing loop.
    let times = sample(cfg, || {
        let _ = std::hint::black_box(solver.solve(&problem, opts));
    });

    Ok(MethodResult {
        method_label: method_label(kind, opts),
        time: Summary::of(&times),
        alloc_bytes: snap.bytes,
        peak_rss_bytes: alloc::peak_rss_bytes(),
        mape: acc,
        trajectory,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::WorkloadSpec;

    #[test]
    fn run_method_all_backends() {
        let w = Workload::consistent(WorkloadSpec::new(120, 12, 77));
        let cfg = BenchConfig::quick();
        let opts = table1_opts(4, 1);
        for kind in [SolverKind::Qr, SolverKind::Bak, SolverKind::Bakp] {
            let r = run_method(&w, kind, &opts, &cfg).expect("consistent workload");
            assert!(r.time.min > 0.0, "{}", r.method_label);
            assert!(r.mape < 1e-2, "{} mape={}", r.method_label, r.mape);
            if cfg!(target_os = "linux") {
                assert!(
                    r.peak_rss_bytes.unwrap_or(0) > 0,
                    "{} VmHWM missing",
                    r.method_label
                );
            }
        }
    }

    #[test]
    fn run_method_honours_passed_options() {
        // A starved budget (1 sweep, no tolerance) must be visibly less
        // accurate than the Table-1 regime — i.e. cfg is not ignored.
        let w = Workload::consistent(WorkloadSpec::new(200, 30, 78));
        let cfg = BenchConfig::quick();
        let starved = SolveOptions::builder().max_sweeps(1).tol(0.0).build();
        let loose = run_method(&w, SolverKind::Bak, &starved, &cfg).unwrap();
        let tight = run_method(&w, SolverKind::Bak, &table1_opts(50, 1), &cfg).unwrap();
        assert!(
            loose.mape > tight.mape * 10.0,
            "starved {} vs tight {}",
            loose.mape,
            tight.mape
        );
    }

    #[test]
    fn rank_deficient_workload_degrades_gracefully() {
        // Duplicate a column: QR must report the failure, not panic.
        let mut w = Workload::consistent(WorkloadSpec::new(60, 6, 79));
        let c0 = w.x.col(0).to_vec();
        w.x.col_mut(1).copy_from_slice(&c0);
        let r = run_method(&w, SolverKind::Qr, &table1_opts(4, 1), &BenchConfig::quick());
        assert!(matches!(r, Err(SolverError::RankDeficient { .. })), "{r:?}");
    }

    #[test]
    fn auto_kind_is_not_measurable() {
        let w = Workload::consistent(WorkloadSpec::new(30, 3, 80));
        let r = run_method(&w, SolverKind::Auto, &table1_opts(4, 1), &BenchConfig::quick());
        assert!(matches!(r, Err(SolverError::Unavailable { .. })), "{r:?}");
    }

    #[test]
    fn labels_distinct() {
        let o = table1_opts(50, 2);
        assert_ne!(method_label(SolverKind::Qr, &o), method_label(SolverKind::Bak, &o));
        assert!(method_label(SolverKind::Bakp, &o).contains("50"));
        assert_eq!(method_label(SolverKind::Cgls, &o), "CGLS");
    }

    #[test]
    fn downsample_caps_and_keeps_the_endpoint() {
        let history: Vec<f64> = (0..100).map(|k| 1.0 / (k + 1) as f64).collect();
        let t = downsample_history(&history, 1, 100, 32);
        assert!(t.len() <= 32, "{}", t.len());
        assert_eq!(t.first().unwrap().0, 1);
        assert_eq!(t.last().unwrap().0, 100, "endpoint kept");
        assert!((t.last().unwrap().1 - (1.0f64 / 100.0).sqrt()).abs() < 1e-12);
        for w in t.windows(2) {
            assert!(w[0].0 < w[1].0, "sweeps strictly increase");
        }
        // Short histories pass through untouched.
        let short = downsample_history(&[4.0, 1.0], 1, 2, 32);
        assert_eq!(short, vec![(1, 2.0), (2, 1.0)]);
        assert!(downsample_history(&[], 1, 0, 32).is_empty());
    }

    #[test]
    fn downsample_respects_check_every() {
        // 5 checkpoints at check_every=3 with 14 total sweeps: the last
        // check happens at the final sweep, not at 15.
        let t = downsample_history(&[1.0; 5], 3, 14, 32);
        assert_eq!(t.iter().map(|p| p.0).collect::<Vec<_>>(), vec![3, 6, 9, 12, 14]);
    }

    #[test]
    fn iterative_methods_record_a_trajectory() {
        let w = Workload::consistent(WorkloadSpec::new(150, 10, 81));
        let cfg = BenchConfig::quick();
        let opts = table1_opts(4, 1);
        let bak = run_method(&w, SolverKind::Bak, &opts, &cfg).unwrap();
        assert!(bak.trajectory.len() >= 2, "{:?}", bak.trajectory);
        assert!(bak.trajectory.len() <= TRAJECTORY_CAP);
        // Residual norms are finite and end low on a consistent system.
        assert!(bak.trajectory.iter().all(|p| p.1.is_finite()));
        assert!(bak.trajectory.last().unwrap().1 < bak.trajectory[0].1);
        // A direct method collapses to its single terminal residual.
        let qr = run_method(&w, SolverKind::Qr, &opts, &cfg).unwrap();
        assert_eq!(qr.trajectory.len(), 1);
    }

    #[test]
    fn table1_opts_paper_regime() {
        let o = table1_opts(50, 4);
        assert_eq!(o.thr, 50);
        assert_eq!(o.threads, 4);
        assert!(o.tol > 0.0);
    }
}
