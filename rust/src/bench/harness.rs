//! Measurement runner shared by the bench binaries: times a solver on a
//! workload with warmup + samples, tracks allocations (when the bench
//! binary installs [`crate::util::alloc::CountingAlloc`]) and computes the
//! paper's accuracy metric.

use crate::baselines::qr::lstsq_qr;
use crate::linalg::Mat;
use crate::solver::{solve_bak, solve_bakp, SolveOptions};
use crate::util::alloc;
use crate::util::stats::{mape, Summary};
use crate::util::timer::{sample, BenchConfig};

use super::workload::Workload;

/// Which method a measurement ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Householder-QR least squares (the paper's "LAPACK" column).
    Lapack,
    /// Algorithm 1.
    Bak,
    /// Algorithm 2 with (thr, threads).
    Bakp { thr: usize, threads: usize },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Lapack => "LAPACK(QR)".into(),
            Method::Bak => "BAK".into(),
            Method::Bakp { thr, threads } => format!("BAKP(thr={thr},t={threads})"),
        }
    }
}

/// One measured method on one workload.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method_label: String,
    pub time: Summary,
    /// Bytes allocated by ONE run (0 unless the counting allocator is the
    /// binary's global allocator).
    pub alloc_bytes: u64,
    /// MAPE of the solution against the planted coefficients.
    pub mape: f64,
}

impl MethodResult {
    pub fn time_ms(&self) -> f64 {
        self.time.min * 1e3 // @btime semantics: minimum over samples
    }

    pub fn mem_mib(&self) -> f64 {
        alloc::mib(self.alloc_bytes)
    }
}

/// Solver options used for Table-1 measurements: tolerance chosen to land
/// in the paper's MAPE regime.
pub fn table1_opts(thr: usize, threads: usize) -> SolveOptions {
    SolveOptions {
        max_sweeps: 200,
        tol: 1e-6,
        thr,
        threads,
        check_every: 1,
        ..SolveOptions::default()
    }
}

/// Run one method on one workload.
pub fn run_method(w: &Workload, method: Method, cfg: &BenchConfig) -> MethodResult {
    let solve = |x: &Mat, y: &[f32]| -> Vec<f32> {
        match method {
            Method::Lapack => lstsq_qr(x, y).expect("qr baseline failed"),
            Method::Bak => solve_bak(x, y, &table1_opts(50, 1)).a,
            Method::Bakp { thr, threads } => {
                solve_bakp(x, y, &table1_opts(thr, threads)).a
            }
        }
    };

    // Allocation measurement: one tracked run.
    let (a_hat, snap) = alloc::measure(|| solve(&w.x, &w.y));
    let acc = w.a_true.as_ref().map(|t| mape(&a_hat, t)).unwrap_or(f64::NAN);

    // Timing loop.
    let times = sample(cfg, || {
        std::hint::black_box(solve(&w.x, &w.y));
    });

    MethodResult {
        method_label: method.label(),
        time: Summary::of(&times),
        alloc_bytes: snap.bytes,
        mape: acc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::WorkloadSpec;

    #[test]
    fn run_method_all_backends() {
        let w = Workload::consistent(WorkloadSpec::new(120, 12, 77));
        let cfg = BenchConfig::quick();
        for m in [Method::Lapack, Method::Bak, Method::Bakp { thr: 4, threads: 1 }] {
            let r = run_method(&w, m, &cfg);
            assert!(r.time.min > 0.0, "{}", r.method_label);
            assert!(r.mape < 1e-2, "{} mape={}", r.method_label, r.mape);
        }
    }

    #[test]
    fn labels_distinct() {
        assert_ne!(Method::Lapack.label(), Method::Bak.label());
        assert!(Method::Bakp { thr: 50, threads: 2 }.label().contains("50"));
    }

    #[test]
    fn table1_opts_paper_regime() {
        let o = table1_opts(50, 4);
        assert_eq!(o.thr, 50);
        assert_eq!(o.threads, 4);
        assert!(o.tol > 0.0);
    }
}
