//! The paper's published numbers (Table 1), used by the bench binaries to
//! print paper-vs-measured side by side.
//!
//! Times are milliseconds, memory is MiB, accuracy is MAPE; "excess" are
//! the paper's LAPACK/BAK(P) ratios. Rows 1-4 ran on a 6-thread desktop,
//! rows 5-12 on an 80-core node with 16 BLAS threads; thr = 50 for rows
//! 1-10 and 1000 for rows 11-12.

/// One Table-1 row as published.
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub id: usize,
    pub vars: usize,
    pub obs: usize,
    pub threads: usize,
    /// The paper's thr parameter for BAKP.
    pub thr: usize,
    pub time_ms_lapack: f64,
    pub time_ms_bak: f64,
    pub time_ms_bakp: f64,
    pub mem_mib_lapack: f64,
    pub mem_mib_bak: f64,
    pub mem_mib_bakp: f64,
    pub mape_lapack: f64,
    pub mape_bak: f64,
    pub mape_bakp: f64,
}

impl PaperRow {
    /// Paper speed-up of BAK over LAPACK ("Time Excess").
    pub fn speedup_bak(&self) -> f64 {
        self.time_ms_lapack / self.time_ms_bak
    }

    /// Paper speed-up of BAKP over LAPACK.
    pub fn speedup_bakp(&self) -> f64 {
        self.time_ms_lapack / self.time_ms_bakp
    }

    /// Paper memory ratio LAPACK/BAK ("Memory Excess").
    pub fn mem_excess_bak(&self) -> f64 {
        self.mem_mib_lapack / self.mem_mib_bak
    }

    pub fn mem_excess_bakp(&self) -> f64 {
        self.mem_mib_lapack / self.mem_mib_bakp
    }
}

/// All 12 rows of Table 1 as published.
pub const TABLE1: [PaperRow; 12] = [
    PaperRow { id: 1, vars: 100, obs: 1_000, threads: 6, thr: 50,
        time_ms_lapack: 12.6, time_ms_bak: 0.262, time_ms_bakp: 2.46,
        mem_mib_lapack: 0.595, mem_mib_bak: 0.335, mem_mib_bakp: 0.461,
        mape_lapack: 2.75e-7, mape_bak: 1.46e-7, mape_bakp: 3.75e-6 },
    PaperRow { id: 2, vars: 100, obs: 1_000_000, threads: 6, thr: 50,
        time_ms_lapack: 3_050.0, time_ms_bak: 227.0, time_ms_bakp: 221.0,
        mem_mib_lapack: 385.0, mem_mib_bak: 34.4, mem_mib_bakp: 42.1,
        mape_lapack: 7.67e-7, mape_bak: 1.69e-7, mape_bakp: 2.44e-8 },
    PaperRow { id: 3, vars: 1_000, obs: 10_000, threads: 6, thr: 50,
        time_ms_lapack: 825.0, time_ms_bak: 48.9, time_ms_bakp: 32.7,
        mem_mib_lapack: 46.7, mem_mib_bak: 4.01, mem_mib_bakp: 3.45,
        mape_lapack: 3.59e-7, mape_bak: 3.15e-7, mape_bakp: 1.60e-6 },
    PaperRow { id: 4, vars: 1_000, obs: 100_000, threads: 6, thr: 50,
        time_ms_lapack: 9_270.0, time_ms_bak: 470.0, time_ms_bakp: 158.0,
        mem_mib_lapack: 390.0, mem_mib_bak: 10.6, mem_mib_bakp: 7.27,
        mape_lapack: 4.05e-7, mape_bak: 2.01e-7, mape_bakp: 1.80e-7 },
    PaperRow { id: 5, vars: 100, obs: 1_000, threads: 16, thr: 50,
        time_ms_lapack: 5.25, time_ms_bak: 0.353, time_ms_bakp: 4.44,
        mem_mib_lapack: 0.595, mem_mib_bak: 0.308, mem_mib_bakp: 0.629,
        mape_lapack: 2.70e-7, mape_bak: 1.51e-7, mape_bakp: 4.06e-6 },
    PaperRow { id: 6, vars: 100, obs: 1_000_000, threads: 16, thr: 50,
        time_ms_lapack: 1_920.0, time_ms_bak: 320.0, time_ms_bakp: 82.1,
        mem_mib_lapack: 385.0, mem_mib_bak: 34.4, mem_mib_bakp: 34.5,
        mape_lapack: 7.96e-7, mape_bak: 1.94e-7, mape_bakp: 6.92e-7 },
    PaperRow { id: 7, vars: 1_000, obs: 10_000, threads: 16, thr: 50,
        time_ms_lapack: 266.0, time_ms_bak: 74.1, time_ms_bakp: 28.2,
        mem_mib_lapack: 46.7, mem_mib_bak: 4.27, mem_mib_bakp: 4.71,
        mape_lapack: 3.63e-7, mape_bak: 3.08e-7, mape_bakp: 1.58e-6 },
    PaperRow { id: 8, vars: 1_000, obs: 100_000, threads: 16, thr: 50,
        time_ms_lapack: 4_040.0, time_ms_bak: 433.0, time_ms_bakp: 133.0,
        mem_mib_lapack: 390.0, mem_mib_bak: 8.72, mem_mib_bakp: 8.02,
        mape_lapack: 3.77e-7, mape_bak: 2.02e-7, mape_bakp: 1.95e-7 },
    PaperRow { id: 9, vars: 1_000, obs: 1_000_000, threads: 16, thr: 50,
        time_ms_lapack: 51_400.0, time_ms_bak: 4_120.0, time_ms_bakp: 1_210.0,
        mem_mib_lapack: 3_740.0, mem_mib_bak: 42.7, mem_mib_bakp: 43.5,
        mape_lapack: 8.21e-7, mape_bak: 2.06e-7, mape_bakp: 2.27e-7 },
    PaperRow { id: 10, vars: 1_000, obs: 10_000_000, threads: 16, thr: 50,
        time_ms_lapack: 535_000.0, time_ms_bak: 45_200.0, time_ms_bakp: 10_600.0,
        mem_mib_lapack: 37_300.0, mem_mib_bak: 344.0, mem_mib_bakp: 344.0,
        mape_lapack: 0.0, mape_bak: 0.0, mape_bakp: 0.0 },
    PaperRow { id: 11, vars: 10_000, obs: 100_000, threads: 16, thr: 1000,
        time_ms_lapack: 317_000.0, time_ms_bak: 8_970.0, time_ms_bakp: 2_960.0,
        mem_mib_lapack: 4_480.0, mem_mib_bak: 42.7, mem_mib_bakp: 29.7,
        mape_lapack: 0.0, mape_bak: 0.0, mape_bakp: 0.0 },
    PaperRow { id: 12, vars: 10_000, obs: 1_000_000, threads: 16, thr: 1000,
        time_ms_lapack: 4_380_000.0, time_ms_bak: 117_000.0, time_ms_bakp: 17_800.0,
        mem_mib_lapack: 38_000.0, mem_mib_bak: 96.6, mem_mib_bakp: 69.8,
        mape_lapack: 0.0, mape_bak: 0.0, mape_bakp: 0.0 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_rows() {
        assert_eq!(TABLE1.len(), 12);
        for (i, r) in TABLE1.iter().enumerate() {
            assert_eq!(r.id, i + 1);
        }
    }

    #[test]
    fn all_rows_tall() {
        // Every Table-1 system has obs >= vars (tall): the regime the
        // paper's speedups live in.
        for r in &TABLE1 {
            assert!(r.obs >= r.vars, "row {}", r.id);
        }
    }

    #[test]
    fn speedups_match_paper_headline() {
        // Paper claims up to O(10^3) speed-up; row 12 is the largest.
        let s: f64 = TABLE1[11].speedup_bak();
        assert!(s > 30.0 && s < 100.0, "bak speedup row12 = {s}");
        let sp: f64 = TABLE1[11].speedup_bakp();
        assert!(sp > 200.0, "bakp speedup row12 = {sp}");
        // BAK wins on every row in time.
        for r in &TABLE1 {
            assert!(r.speedup_bak() > 1.0, "row {}", r.id);
        }
    }

    #[test]
    fn memory_excess_positive() {
        for r in &TABLE1 {
            assert!(r.mem_excess_bak() > 1.0, "row {}", r.id);
            assert!(r.mem_excess_bakp() > 0.9, "row {}", r.id);
        }
    }
}
