//! Workload generation for the paper's evaluation.
//!
//! Table 1 benchmarks dense iid-Gaussian systems with a planted exact
//! solution ("single float precision", consistent systems — MAPE against
//! the planted coefficients is the accuracy metric). Figure 2 uses
//! sparse-support regression targets.

use crate::linalg::{blas1, Mat};
use crate::sparse::{CooBuilder, CscMat};
use crate::util::rng::Rng;

/// Specification of one benchmark system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub obs: usize,
    pub vars: usize,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn new(obs: usize, vars: usize, seed: u64) -> Self {
        Self { obs, vars, seed }
    }

    /// Scale both dimensions by `f` (>= 1 keeps at least one row/col).
    pub fn scaled(&self, f: f64) -> Self {
        Self {
            obs: ((self.obs as f64 * f) as usize).max(4),
            vars: ((self.vars as f64 * f) as usize).max(2),
            seed: self.seed,
        }
    }

    /// f32 bytes of the input matrix.
    pub fn matrix_bytes(&self) -> usize {
        self.obs * self.vars * 4
    }
}

/// A generated system with its planted ground truth.
pub struct Workload {
    pub spec: WorkloadSpec,
    pub x: Mat,
    pub y: Vec<f32>,
    /// The planted coefficients (None for inconsistent workloads).
    pub a_true: Option<Vec<f32>>,
}

impl Workload {
    /// Dense consistent system: y = X a_true exactly (Table 1 workload).
    pub fn consistent(spec: WorkloadSpec) -> Self {
        let mut rng = Rng::seed(spec.seed);
        let x = Mat::randn(&mut rng, spec.obs, spec.vars);
        let a_true: Vec<f32> = (0..spec.vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        Self { spec, x, y, a_true: Some(a_true) }
    }

    /// Noisy tall regression: y = X a_true + sigma * noise.
    pub fn noisy(spec: WorkloadSpec, sigma: f32) -> Self {
        let mut rng = Rng::seed(spec.seed);
        let x = Mat::randn(&mut rng, spec.obs, spec.vars);
        let a_true: Vec<f32> = (0..spec.vars).map(|_| rng.normal_f32()).collect();
        let mut y = x.matvec(&a_true);
        for v in y.iter_mut() {
            *v += sigma * rng.normal_f32();
        }
        Self { spec, x, y, a_true: Some(a_true) }
    }

    /// Sparse-support target for feature selection (Figure 2 workload):
    /// k planted features with descending weights + small noise.
    pub fn sparse_support(spec: WorkloadSpec, k: usize, noise: f32) -> (Self, Vec<usize>) {
        let mut rng = Rng::seed(spec.seed);
        let x = Mat::randn(&mut rng, spec.obs, spec.vars);
        let support = rng.sample_indices(spec.vars, k.min(spec.vars));
        let mut y = vec![0.0f32; spec.obs];
        for (rank, &j) in support.iter().enumerate() {
            // Descending, well-separated weights.
            let w = 2.0f32 * 0.7f32.powi(rank as i32) * if rank % 2 == 0 { 1.0 } else { -1.0 };
            blas1::axpy(w, x.col(j), &mut y);
        }
        for v in y.iter_mut() {
            *v += noise * rng.normal_f32();
        }
        (Self { spec, x, y, a_true: None }, support)
    }
}

/// A generated sparse system (CSC) with its planted ground truth — the
/// O(nnz) workload class for `benches/sparse_speedup.rs` and the CLI's
/// `--sparse` mode.
pub struct SparseWorkload {
    pub spec: WorkloadSpec,
    pub x: CscMat,
    pub y: Vec<f32>,
    pub a_true: Vec<f32>,
}

impl SparseWorkload {
    /// Uniform-random sparsity: each cell is nonzero independently with
    /// probability `density` (iid normal values), plus one guaranteed
    /// entry per column so every planted coefficient is identifiable.
    /// y = X a_true exactly.
    pub fn uniform(spec: WorkloadSpec, density: f64) -> Self {
        let mut rng = Rng::seed(spec.seed);
        let mut b = CooBuilder::new(spec.obs, spec.vars);
        for j in 0..spec.vars {
            b.push(rng.below(spec.obs), j, rng.normal_f32());
            for i in 0..spec.obs {
                if rng.uniform() < density {
                    b.push(i, j, rng.normal_f32());
                }
            }
        }
        Self::planted(spec, b.to_csc(), &mut rng)
    }

    /// Power-law column occupancy: column j gets
    /// `max(1, obs * max_density * (j+1)^-alpha)` nonzeros at random rows
    /// — a few dense "head" columns and a long sparse tail, the shape of
    /// one-hot / n-gram feature matrices.
    pub fn power_law(spec: WorkloadSpec, alpha: f64, max_density: f64) -> Self {
        let mut rng = Rng::seed(spec.seed);
        let mut b = CooBuilder::new(spec.obs, spec.vars);
        for j in 0..spec.vars {
            let frac = max_density * ((j + 1) as f64).powf(-alpha);
            let nnz = ((spec.obs as f64 * frac) as usize).clamp(1, spec.obs);
            for i in rng.sample_indices(spec.obs, nnz) {
                b.push(i, j, rng.normal_f32());
            }
        }
        Self::planted(spec, b.to_csc(), &mut rng)
    }

    fn planted(spec: WorkloadSpec, x: CscMat, rng: &mut Rng) -> Self {
        let a_true: Vec<f32> = (0..spec.vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        Self { spec, x, y, a_true }
    }

    /// The same system materialised dense (for sparse-vs-dense benches).
    pub fn densified(&self) -> Mat {
        self.x.to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_is_exact() {
        let w = Workload::consistent(WorkloadSpec::new(50, 10, 7));
        let a = w.a_true.unwrap();
        let e = crate::linalg::residual(&w.x, &w.y, &a);
        assert!(blas1::nrm2(&e) < 1e-3);
    }

    #[test]
    fn deterministic_per_seed() {
        let w1 = Workload::consistent(WorkloadSpec::new(20, 5, 3));
        let w2 = Workload::consistent(WorkloadSpec::new(20, 5, 3));
        assert_eq!(w1.x, w2.x);
        assert_eq!(w1.y, w2.y);
        let w3 = Workload::consistent(WorkloadSpec::new(20, 5, 4));
        assert_ne!(w3.y, w1.y);
    }

    #[test]
    fn noisy_has_residual() {
        let w = Workload::noisy(WorkloadSpec::new(100, 10, 5), 1.0);
        let a = w.a_true.unwrap();
        let e = crate::linalg::residual(&w.x, &w.y, &a);
        assert!(blas1::nrm2(&e) > 1.0);
    }

    #[test]
    fn sparse_support_distinct_indices() {
        let (_, support) = Workload::sparse_support(WorkloadSpec::new(100, 30, 9), 5, 0.01);
        assert_eq!(support.len(), 5);
        let mut s = support.clone();
        s.dedup();
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn scaled_shrinks() {
        let s = WorkloadSpec::new(1000, 100, 1).scaled(0.1);
        assert_eq!(s.obs, 100);
        assert_eq!(s.vars, 10);
        // Floor kicks in.
        let tiny = WorkloadSpec::new(10, 4, 1).scaled(0.01);
        assert!(tiny.obs >= 4 && tiny.vars >= 2);
    }

    #[test]
    fn matrix_bytes() {
        assert_eq!(WorkloadSpec::new(10, 10, 0).matrix_bytes(), 400);
    }

    #[test]
    fn sparse_uniform_is_consistent_and_near_target_density() {
        let w = SparseWorkload::uniform(WorkloadSpec::new(400, 50, 11), 0.05);
        let e = {
            let xa = w.x.matvec(&w.a_true);
            w.y.iter().zip(&xa).map(|(&a, &b)| a - b).collect::<Vec<f32>>()
        };
        assert!(blas1::nrm2(&e) < 1e-3, "planted solution must be exact");
        // Density lands near the target (+1/obs for the guaranteed entry).
        let d = w.x.density();
        assert!(d > 0.02 && d < 0.09, "density={d}");
    }

    #[test]
    fn sparse_uniform_deterministic_per_seed() {
        let w1 = SparseWorkload::uniform(WorkloadSpec::new(60, 8, 5), 0.1);
        let w2 = SparseWorkload::uniform(WorkloadSpec::new(60, 8, 5), 0.1);
        assert_eq!(w1.x, w2.x);
        assert_eq!(w1.y, w2.y);
        assert_eq!(w1.densified(), w1.x.to_dense());
    }

    #[test]
    fn sparse_power_law_head_heavier_than_tail() {
        let w = SparseWorkload::power_law(WorkloadSpec::new(500, 40, 7), 1.0, 0.5);
        let head = w.x.col(0).0.len();
        let tail = w.x.col(39).0.len();
        assert!(head > tail, "head {head} vs tail {tail}");
        assert!(w.x.col(39).0.len() >= 1, "every column keeps >= 1 entry");
        // Still an exactly consistent system.
        let xa = w.x.matvec(&w.a_true);
        let e: Vec<f32> = w.y.iter().zip(&xa).map(|(&a, &b)| a - b).collect();
        assert!(blas1::nrm2(&e) < 1e-3);
    }
}
