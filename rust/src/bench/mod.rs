//! Benchmark harness (the offline registry has no criterion): workload
//! generators, paper reference numbers, measurement runners and table
//! printers shared by the `rust/benches/*` binaries.

pub mod workload;
pub mod paper;
pub mod harness;

pub use harness::{run_method, MethodResult};
pub use workload::{WorkloadSpec, Workload};
