//! Benchmark harness (the offline registry has no criterion): workload
//! generators, paper reference numbers, measurement runners and table
//! printers shared by the `rust/benches/*` binaries.

pub mod workload;
pub mod paper;
pub mod harness;

pub use harness::{
    downsample_history, method_label, run_method, table1_opts, MethodResult, TRAJECTORY_CAP,
};
pub use workload::{WorkloadSpec, Workload};
