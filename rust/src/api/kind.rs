//! The canonical solver namespace: [`SolverKind`] plus the [`registry`] of
//! implementations. CLI `--backend` parsing, coordinator routing, and the
//! bench harness all resolve through here.

use std::str::FromStr;

use super::backends::{
    BakMultiSolver, BakParSolver, BakSolver, BakpSolver, CglsSolver, CholeskySolver,
    GaussSolver, GaussSouthwellSolver, KaczmarzParSolver, KaczmarzSolver, PjrtSolver,
    QrSolver,
};
use super::{Capabilities, Solver, SolverError};

/// Every solver the crate ships, plus [`SolverKind::Auto`] for "let the
/// router pick from the problem shape".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum SolverKind {
    /// Algorithm 1 — sequential cyclic coordinate descent.
    Bak,
    /// Algorithm 2 — block-"parallel" CD with stale in-block errors.
    Bakp,
    /// Column-partitioned SolveBak on real threads: concurrent per-block
    /// inner sweeps with an every-sweep merge sync.
    BakPar,
    /// Multi-RHS SolveBak (one matrix walk serves every right-hand side).
    BakMulti,
    /// Randomized Kaczmarz (row-action dual).
    Kaczmarz,
    /// Row-partitioned parallel Kaczmarz with averaging sync.
    KaczmarzPar,
    /// Greedy Gauss-Southwell column selection.
    GaussSouthwell,
    /// Householder-QR least squares (the paper's "LAPACK" comparator).
    Qr,
    /// Normal equations via Cholesky.
    Cholesky,
    /// Gaussian elimination with partial pivoting (square systems).
    Gauss,
    /// Conjugate gradient on the normal equations.
    Cgls,
    /// AOT-compiled sweep artifacts executed through PJRT.
    Pjrt,
    /// Routing pseudo-kind: resolved by the coordinator's router.
    #[default]
    Auto,
}

impl SolverKind {
    /// Every concrete implementation, in registry order (excludes `Auto`).
    pub const CONCRETE: [SolverKind; 12] = [
        SolverKind::Bak,
        SolverKind::Bakp,
        SolverKind::BakPar,
        SolverKind::BakMulti,
        SolverKind::Kaczmarz,
        SolverKind::KaczmarzPar,
        SolverKind::GaussSouthwell,
        SolverKind::Qr,
        SolverKind::Cholesky,
        SolverKind::Gauss,
        SolverKind::Cgls,
        SolverKind::Pjrt,
    ];

    /// Canonical lowercase name; round-trips through [`FromStr`].
    pub fn as_str(self) -> &'static str {
        match self {
            SolverKind::Bak => "bak",
            SolverKind::Bakp => "bakp",
            SolverKind::BakPar => "bak_par",
            SolverKind::BakMulti => "bak_multi",
            SolverKind::Kaczmarz => "kaczmarz",
            SolverKind::KaczmarzPar => "kaczmarz_par",
            SolverKind::GaussSouthwell => "gauss_southwell",
            SolverKind::Qr => "qr",
            SolverKind::Cholesky => "cholesky",
            SolverKind::Gauss => "gauss",
            SolverKind::Cgls => "cgls",
            SolverKind::Pjrt => "pjrt",
            SolverKind::Auto => "auto",
        }
    }

    /// True for the router placeholder.
    pub fn is_auto(self) -> bool {
        self == SolverKind::Auto
    }

    /// The capability-matrix entry for this kind (`None` for `Auto`).
    ///
    /// This is the single source of truth — the [`Solver`] impls
    /// delegate here — and it allocates nothing, so routing and
    /// validation hot paths can consult it per request.
    pub fn capabilities(self) -> Option<Capabilities> {
        const ITERATIVE: Capabilities = Capabilities {
            supports_wide: true,
            iterative: true,
            needs_square: false,
            warm_start: false,
            supports_sparse: false,
            supports_parallel: false,
            supports_streaming: false,
            supports_probe: true,
            supports_sharding: false,
        };
        match self {
            SolverKind::Bak => Some(Capabilities {
                warm_start: true,
                supports_sparse: true,
                supports_streaming: true,
                ..ITERATIVE
            }),
            // Bakp threads its in-block phases on the dense path; the
            // block-partitioned variants scale whole sweeps.
            SolverKind::Bakp => Some(Capabilities {
                supports_sparse: true,
                supports_parallel: true,
                ..ITERATIVE
            }),
            // Only the block-partitioned pair shards across processes:
            // their between-sync block iterates are independent, so the
            // cluster layer's mass-weighted merge reproduces the
            // in-process sync bit-for-bit.
            SolverKind::BakPar | SolverKind::KaczmarzPar => Some(Capabilities {
                supports_sparse: true,
                supports_parallel: true,
                supports_sharding: true,
                ..ITERATIVE
            }),
            // The streaming-native trio (bak, kaczmarz, bak_multi) run
            // their serial inner steps over disk chunks bit-identically;
            // the block-parallel variants interleave block-local work and
            // cannot consume a single sequential chunk stream.
            SolverKind::Kaczmarz => Some(Capabilities {
                supports_sparse: true,
                supports_streaming: true,
                ..ITERATIVE
            }),
            SolverKind::Cgls => Some(Capabilities { supports_sparse: true, ..ITERATIVE }),
            SolverKind::BakMulti => {
                Some(Capabilities { supports_streaming: true, ..ITERATIVE })
            }
            SolverKind::GaussSouthwell => Some(ITERATIVE),
            // PJRT executes opaque compiled artifacts: there is no place
            // to observe a per-sweep residual, so no probe support.
            SolverKind::Pjrt => Some(Capabilities { supports_probe: false, ..ITERATIVE }),
            SolverKind::Qr => Some(Capabilities {
                iterative: false,
                supports_probe: false,
                ..ITERATIVE
            }),
            SolverKind::Cholesky => Some(Capabilities {
                supports_wide: false,
                iterative: false,
                needs_square: false,
                warm_start: false,
                supports_sparse: false,
                supports_parallel: false,
                supports_streaming: false,
                supports_probe: false,
                supports_sharding: false,
            }),
            SolverKind::Gauss => Some(Capabilities {
                supports_wide: false,
                iterative: false,
                needs_square: true,
                warm_start: false,
                supports_sparse: false,
                supports_parallel: false,
                supports_streaming: false,
                supports_probe: false,
                supports_sharding: false,
            }),
            SolverKind::Auto => None,
        }
    }
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for SolverKind {
    type Err = SolverError;

    /// Accepts the canonical names plus historical aliases (`lapack` for
    /// the QR baseline, `-` for `_`, `gs` for Gauss-Southwell).
    fn from_str(s: &str) -> Result<Self, SolverError> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "bak" => Ok(SolverKind::Bak),
            "bakp" => Ok(SolverKind::Bakp),
            "bak_par" | "bakpar" => Ok(SolverKind::BakPar),
            "bak_multi" | "bakmulti" => Ok(SolverKind::BakMulti),
            "kaczmarz" => Ok(SolverKind::Kaczmarz),
            "kaczmarz_par" | "kaczmarzpar" => Ok(SolverKind::KaczmarzPar),
            "gauss_southwell" | "gs" => Ok(SolverKind::GaussSouthwell),
            "qr" | "lapack" => Ok(SolverKind::Qr),
            "cholesky" => Ok(SolverKind::Cholesky),
            "gauss" => Ok(SolverKind::Gauss),
            "cgls" => Ok(SolverKind::Cgls),
            "pjrt" => Ok(SolverKind::Pjrt),
            "auto" => Ok(SolverKind::Auto),
            other => Err(SolverError::UnknownKind(other.to_string())),
        }
    }
}

/// Construct the implementation for a concrete kind (`None` for `Auto`).
///
/// The PJRT entry comes back detached (no engine); callers holding a
/// loaded [`crate::runtime::Engine`] should build
/// [`PjrtSolver::with_engine`] instead.
pub fn solver_for(kind: SolverKind) -> Option<Box<dyn Solver>> {
    match kind {
        SolverKind::Bak => Some(Box::new(BakSolver)),
        SolverKind::Bakp => Some(Box::new(BakpSolver)),
        SolverKind::BakPar => Some(Box::new(BakParSolver)),
        SolverKind::BakMulti => Some(Box::new(BakMultiSolver)),
        SolverKind::Kaczmarz => Some(Box::new(KaczmarzSolver)),
        SolverKind::KaczmarzPar => Some(Box::new(KaczmarzParSolver)),
        SolverKind::GaussSouthwell => Some(Box::new(GaussSouthwellSolver)),
        SolverKind::Qr => Some(Box::new(QrSolver)),
        SolverKind::Cholesky => Some(Box::new(CholeskySolver)),
        SolverKind::Gauss => Some(Box::new(GaussSolver)),
        SolverKind::Cgls => Some(Box::new(CglsSolver)),
        SolverKind::Pjrt => Some(Box::new(PjrtSolver::detached())),
        SolverKind::Auto => None,
    }
}

/// All registered implementations, in [`SolverKind::CONCRETE`] order.
pub fn registry() -> Vec<Box<dyn Solver>> {
    SolverKind::CONCRETE
        .iter()
        .map(|&k| solver_for(k).expect("every concrete kind is registered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_concrete_kinds() {
        let reg = registry();
        assert_eq!(reg.len(), SolverKind::CONCRETE.len());
        for (s, &k) in reg.iter().zip(SolverKind::CONCRETE.iter()) {
            assert_eq!(s.kind(), k);
            assert_eq!(s.name(), k.as_str());
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> =
            SolverKind::CONCRETE.iter().map(|k| k.as_str()).collect();
        names.push(SolverKind::Auto.as_str());
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
    }

    #[test]
    fn from_str_aliases() {
        assert_eq!("lapack".parse::<SolverKind>().unwrap(), SolverKind::Qr);
        assert_eq!("BAK".parse::<SolverKind>().unwrap(), SolverKind::Bak);
        assert_eq!(
            "bak-multi".parse::<SolverKind>().unwrap(),
            SolverKind::BakMulti
        );
        assert_eq!(
            "gs".parse::<SolverKind>().unwrap(),
            SolverKind::GaussSouthwell
        );
        assert!(matches!(
            "gpu4000".parse::<SolverKind>(),
            Err(SolverError::UnknownKind(_))
        ));
    }

    #[test]
    fn kind_capabilities_match_registry() {
        for s in registry() {
            assert_eq!(Some(s.capabilities()), s.kind().capabilities(), "{}", s.name());
        }
        assert!(SolverKind::Auto.capabilities().is_none());
    }

    #[test]
    fn sparse_native_kinds_are_exactly_the_iterative_sextet() {
        let native: Vec<SolverKind> = SolverKind::CONCRETE
            .iter()
            .copied()
            .filter(|k| k.capabilities().is_some_and(|c| c.supports_sparse))
            .collect();
        assert_eq!(
            native,
            vec![
                SolverKind::Bak,
                SolverKind::Bakp,
                SolverKind::BakPar,
                SolverKind::Kaczmarz,
                SolverKind::KaczmarzPar,
                SolverKind::Cgls
            ]
        );
    }

    #[test]
    fn parallel_kinds_are_the_block_trio() {
        let par: Vec<SolverKind> = SolverKind::CONCRETE
            .iter()
            .copied()
            .filter(|k| k.capabilities().is_some_and(|c| c.supports_parallel))
            .collect();
        assert_eq!(
            par,
            vec![SolverKind::Bakp, SolverKind::BakPar, SolverKind::KaczmarzPar]
        );
    }

    #[test]
    fn streaming_kinds_are_the_serial_trio() {
        let stream: Vec<SolverKind> = SolverKind::CONCRETE
            .iter()
            .copied()
            .filter(|k| k.capabilities().is_some_and(|c| c.supports_streaming))
            .collect();
        assert_eq!(
            stream,
            vec![SolverKind::Bak, SolverKind::BakMulti, SolverKind::Kaczmarz]
        );
    }

    #[test]
    fn probe_kinds_are_the_loop_observable_iteratives() {
        let probed: Vec<SolverKind> = SolverKind::CONCRETE
            .iter()
            .copied()
            .filter(|k| k.capabilities().is_some_and(|c| c.supports_probe))
            .collect();
        assert_eq!(
            probed,
            vec![
                SolverKind::Bak,
                SolverKind::Bakp,
                SolverKind::BakPar,
                SolverKind::BakMulti,
                SolverKind::Kaczmarz,
                SolverKind::KaczmarzPar,
                SolverKind::GaussSouthwell,
                SolverKind::Cgls
            ]
        );
        // Direct methods and opaque artifact execution never probe.
        for k in [SolverKind::Qr, SolverKind::Cholesky, SolverKind::Gauss, SolverKind::Pjrt] {
            assert!(!k.capabilities().unwrap().supports_probe, "{k}");
        }
    }

    #[test]
    fn sharding_kinds_are_the_block_parallel_pair() {
        let shard: Vec<SolverKind> = SolverKind::CONCRETE
            .iter()
            .copied()
            .filter(|k| k.capabilities().is_some_and(|c| c.supports_sharding))
            .collect();
        assert_eq!(shard, vec![SolverKind::BakPar, SolverKind::KaczmarzPar]);
        // Sharding implies the in-process parallel capability: the
        // cluster merge is the same math as the threaded sync.
        for k in shard {
            assert!(k.capabilities().unwrap().supports_parallel, "{k}");
        }
    }

    #[test]
    fn parallel_aliases_parse() {
        assert_eq!("bak-par".parse::<SolverKind>().unwrap(), SolverKind::BakPar);
        assert_eq!("BAKPAR".parse::<SolverKind>().unwrap(), SolverKind::BakPar);
        assert_eq!(
            "kaczmarz-par".parse::<SolverKind>().unwrap(),
            SolverKind::KaczmarzPar
        );
    }

    #[test]
    fn auto_is_default_and_unregistered() {
        assert_eq!(SolverKind::default(), SolverKind::Auto);
        assert!(SolverKind::Auto.is_auto());
        assert!(solver_for(SolverKind::Auto).is_none());
    }
}
