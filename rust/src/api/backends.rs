//! [`Solver`] implementations: thin adapters from the trait to the
//! underlying free functions in [`crate::solver`], [`crate::baselines`],
//! and [`crate::runtime`]. The free functions stay public and stable; the
//! adapters add shape/capability checking and typed errors.

use std::sync::Arc;

use crate::baselines;
use crate::linalg::blas1;
use crate::runtime::{ArtifactKind, Engine};
use crate::solver::{self, SolveOptions, SolveReport, StopReason};

use super::{report_from_coefficients, Capabilities, Problem, Solver, SolverError, SolverKind};

/// Algorithm 1 — sequential cyclic coordinate descent.
pub struct BakSolver;

impl Solver for BakSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Bak
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.warm_start() {
            Some(a0) => {
                let cninv = solver::colnorms_inv(p.x());
                let mut a = a0.to_vec();
                let mut e = crate::linalg::residual(p.x(), p.y(), &a);
                Ok(solver::bak::solve_bak_warm(
                    p.x(),
                    &cninv,
                    &mut a,
                    &mut e,
                    p.y(),
                    opts,
                ))
            }
            None => Ok(solver::solve_bak(p.x(), p.y(), opts)),
        }
    }
}

/// Algorithm 2 — block CD with stale in-block errors.
pub struct BakpSolver;

impl Solver for BakpSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Bakp
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        Ok(solver::solve_bakp(p.x(), p.y(), opts))
    }
}

/// Multi-RHS SolveBak, run with a single right-hand side. The coordinator
/// uses the underlying [`solver::solve_bak_multi`] directly to amortise
/// whole batches; this adapter makes the kind addressable standalone.
pub struct BakMultiSolver;

impl Solver for BakMultiSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::BakMulti
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        let mut reports = solver::solve_bak_multi(p.x(), &[p.y().to_vec()], opts);
        reports.pop().ok_or_else(|| SolverError::Backend {
            backend: "bak_multi".into(),
            reason: "no report produced".into(),
        })
    }
}

/// Randomized Kaczmarz — row-action dual of SolveBak.
pub struct KaczmarzSolver;

impl Solver for KaczmarzSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Kaczmarz
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        Ok(solver::solve_kaczmarz(p.x(), p.y(), opts))
    }
}

/// Greedy Gauss-Southwell column selection.
pub struct GaussSouthwellSolver;

impl Solver for GaussSouthwellSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::GaussSouthwell
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        Ok(solver::solve_gauss_southwell(p.x(), p.y(), opts))
    }
}

/// Householder-QR least squares (tall) / minimum-norm (wide) — the
/// paper's "LAPACK" comparator.
pub struct QrSolver;

impl Solver for QrSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Qr
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts; // direct method: convergence knobs don't apply
        self.capabilities().check(p.obs(), p.vars())?;
        let a = baselines::qr::lstsq_qr(p.x(), p.y())?;
        Ok(report_from_coefficients(p.x(), p.y(), a))
    }
}

/// Normal equations via Cholesky (tall, full column rank).
pub struct CholeskySolver;

impl Solver for CholeskySolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Cholesky
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts;
        self.capabilities().check(p.obs(), p.vars())?;
        let a = baselines::cholesky::solve_normal_equations(p.x(), p.y(), 0.0)?;
        Ok(report_from_coefficients(p.x(), p.y(), a))
    }
}

/// Gaussian elimination with partial pivoting (square systems only).
pub struct GaussSolver;

impl Solver for GaussSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Gauss
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts;
        self.capabilities().check(p.obs(), p.vars())?;
        let a = baselines::gauss::gauss_solve(p.x(), p.y())?;
        Ok(report_from_coefficients(p.x(), p.y(), a))
    }
}

/// Conjugate gradient on the normal equations.
pub struct CglsSolver;

impl Solver for CglsSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Cgls
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        let rep = baselines::cgls::cgls_solve(p.x(), p.y(), opts.max_sweeps, opts.tol);
        let e = crate::linalg::residual(p.x(), p.y(), &rep.a);
        Ok(SolveReport {
            a: rep.a,
            e,
            history: rep.history,
            y_norm_sq: blas1::sum_sq_f64(p.y()),
            sweeps: rep.iterations,
            stop: if rep.converged {
                StopReason::Converged
            } else {
                StopReason::MaxSweeps
            },
        })
    }
}

/// AOT-compiled sweep artifacts executed through the PJRT engine.
///
/// [`PjrtSolver::detached`] (what the [`super::registry`] hands out) has
/// no engine and reports [`SolverError::Unavailable`]; services that
/// loaded artifacts wrap their engine via [`PjrtSolver::with_engine`].
pub struct PjrtSolver {
    engine: Option<Arc<Engine>>,
}

impl PjrtSolver {
    /// No engine attached; `solve` returns `Unavailable`.
    pub fn detached() -> Self {
        Self { engine: None }
    }

    /// Execute through a loaded engine.
    pub fn with_engine(engine: Arc<Engine>) -> Self {
        Self { engine: Some(engine) }
    }
}

impl Solver for PjrtSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Pjrt
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match &self.engine {
            None => Err(SolverError::Unavailable {
                backend: "pjrt".into(),
                reason: "no engine attached (load artifacts and use with_engine)".into(),
            }),
            Some(eng) => eng
                .solve(p.x(), p.y(), opts, ArtifactKind::BakpSweep)
                .map(|o| o.report)
                .map_err(|e| SolverError::Backend {
                    backend: "pjrt".into(),
                    reason: e.to_string(),
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    #[test]
    fn bak_solver_matches_free_function() {
        let (x, y, _) = planted(700, 150, 20);
        let opts = SolveOptions::accurate();
        let p = Problem::new(&x, &y).unwrap();
        let via_trait = BakSolver.solve(&p, &opts).unwrap();
        let direct = solver::solve_bak(&x, &y, &opts);
        assert_eq!(via_trait.a, direct.a);
    }

    #[test]
    fn bak_warm_start_honoured() {
        let (x, y, a_true) = planted(701, 200, 15);
        let opts = SolveOptions::builder().max_sweeps(1).tol(0.0).build();
        let p = Problem::new(&x, &y).unwrap();
        // One sweep from the truth stays at the truth (residual ~ 0).
        let warm = p.with_warm_start(&a_true).unwrap();
        let rep = BakSolver.solve(&warm, &opts).unwrap();
        assert!(rep.rel_residual() < 1e-4, "rel={}", rep.rel_residual());
        // One cold sweep is measurably worse than starting at the truth.
        let cold = BakSolver.solve(&p, &opts).unwrap();
        assert!(cold.rel_residual() > rep.rel_residual());
    }

    #[test]
    fn gauss_rejects_non_square() {
        let (x, y, _) = planted(702, 30, 10);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            GaussSolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::NeedsSquare { obs: 30, vars: 10 })
        ));
    }

    #[test]
    fn cholesky_rejects_wide() {
        let (x, y, _) = planted(703, 10, 30);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            CholeskySolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::Shape(_))
        ));
    }

    #[test]
    fn qr_rank_deficiency_is_typed_error() {
        let mut rng = Rng::seed(704);
        let mut x = Mat::randn(&mut rng, 12, 3);
        let c0 = x.col(0).to_vec();
        x.col_mut(1).copy_from_slice(&c0);
        let y: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            QrSolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::RankDeficient { .. })
        ));
    }

    #[test]
    fn cgls_report_has_exit_invariant() {
        let (x, y, a_true) = planted(705, 120, 10);
        let p = Problem::new(&x, &y).unwrap();
        let opts = SolveOptions::builder().max_sweeps(100).tol(1e-8).build();
        let rep = CglsSolver.solve(&p, &opts).unwrap();
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
        let fresh = crate::linalg::residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-4);
        }
    }

    #[test]
    fn detached_pjrt_is_unavailable() {
        let (x, y, _) = planted(706, 20, 4);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            PjrtSolver::detached().solve(&p, &SolveOptions::default()),
            Err(SolverError::Unavailable { .. })
        ));
    }
}
