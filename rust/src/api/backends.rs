//! [`Solver`] implementations: thin adapters from the trait to the
//! underlying free functions in [`crate::solver`], [`crate::baselines`],
//! [`crate::sparse::solve`], and [`crate::runtime`]. The free functions
//! stay public and stable; the adapters add shape/capability checking,
//! typed errors, and the dense/sparse representation dispatch: kinds with
//! `supports_sparse` run the native O(nnz) path, everything else goes
//! through [`dense_or_warn`] (materialise + log).

use std::borrow::Cow;
use std::sync::Arc;

use crate::baselines;
use crate::linalg::{blas1, Mat};
use crate::runtime::{ArtifactKind, Engine};
use crate::solver::{self, SolveOptions, SolveReport, StopReason};
use crate::sparse;
use crate::util::log::{emit, Level};

use super::{
    report_from_coefficients, residual_ref, Capabilities, MatrixRef, Problem, Solver,
    SolverError, SolverKind,
};

/// The typed refusal for file-backed matrices on backends without an
/// out-of-core path. Never densify these: the matrix was put on disk
/// precisely because it may not fit in RAM, so "helpfully" materialising
/// it trades a clear error for an OOM kill.
fn streamed_unsupported(backend: &'static str) -> SolverError {
    SolverError::Unavailable {
        backend: backend.into(),
        reason: "no out-of-core path for file-backed (streamed) matrices; \
                 use a streaming-native backend (bak, kaczmarz, bak_multi) \
                 or load the matrix into RAM yourself"
            .into(),
    }
}

/// Dense view of the problem's matrix for a backend without a native
/// sparse path: borrows when already dense; materialises (O(obs*vars))
/// when sparse; refuses streamed input with [`streamed_unsupported`]
/// (out-of-core matrices must never be silently loaded). The first
/// densification per backend logs at Warn; repeat calls — a batch of
/// members against the same matrix, or a bench harness's timing loop —
/// drop to Debug so one request logs the event once instead of once per
/// solve. The coordinator layers a once-per-job `densified_jobs` metric
/// on top of the same event.
fn dense_or_warn<'a>(
    p: &Problem<'a>,
    backend: &'static str,
) -> Result<Cow<'a, Mat>, SolverError> {
    if p.x().is_streamed() {
        return Err(streamed_unsupported(backend));
    }
    if let MatrixRef::SparseCsc(s) = p.x() {
        static WARNED: std::sync::OnceLock<std::sync::Mutex<Vec<&'static str>>> =
            std::sync::OnceLock::new();
        let first = {
            let mut seen = WARNED
                .get_or_init(|| std::sync::Mutex::new(Vec::new()))
                .lock()
                .unwrap();
            if seen.contains(&backend) {
                false
            } else {
                seen.push(backend);
                true
            }
        };
        emit(
            if first { Level::Warn } else { Level::Debug },
            "api",
            format_args!(
                "backend '{backend}' has no native sparse path; densifying {}x{} (nnz={})",
                s.rows(),
                s.cols(),
                s.nnz()
            ),
        );
    }
    Ok(p.x().to_dense())
}

/// Algorithm 1 — sequential cyclic coordinate descent.
pub struct BakSolver;

impl Solver for BakSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Bak
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.x() {
            MatrixRef::Dense(x) => match p.warm_start() {
                Some(a0) => {
                    let cninv = solver::colnorms_inv(x);
                    let mut a = a0.to_vec();
                    // Checkpointed warm state carries its own residual —
                    // resuming from it (instead of recomputing y - Xa) is
                    // what makes a resumed solve bit-identical to an
                    // uninterrupted one.
                    let mut e = match p.warm_residual() {
                        Some(e0) => e0.to_vec(),
                        None => crate::linalg::residual(x, p.y(), &a),
                    };
                    Ok(solver::bak::solve_bak_warm(x, &cninv, &mut a, &mut e, p.y(), opts))
                }
                None => Ok(solver::solve_bak(x, p.y(), opts)),
            },
            MatrixRef::SparseCsc(s) => match p.warm_start() {
                Some(a0) => {
                    let cninv = sparse::solve::colnorms_inv_csc(s);
                    let mut a = a0.to_vec();
                    let mut e = match p.warm_residual() {
                        Some(e0) => e0.to_vec(),
                        None => residual_ref(p.x(), p.y(), &a),
                    };
                    Ok(sparse::solve::solve_bak_csc_warm(
                        s, &cninv, &mut a, &mut e, p.y(), opts,
                    ))
                }
                None => Ok(sparse::solve::solve_bak_csc(s, p.y(), opts)),
            },
            MatrixRef::Streamed(s) => match p.warm_start() {
                Some(a0) => {
                    // Without a stored residual this costs one extra disk
                    // pass (matvec) before the sweeps start.
                    let e = match p.warm_residual() {
                        Some(e0) => e0.to_vec(),
                        None => residual_ref(p.x(), p.y(), a0),
                    };
                    crate::stream::solve_bak_stream_warm(s, p.y(), a0.to_vec(), e, opts)
                        .map(|r| r.report)
                }
                None => crate::stream::solve_bak_stream(s, p.y(), opts).map(|r| r.report),
            },
        }
    }
}

/// Algorithm 2 — block CD with stale in-block errors.
pub struct BakpSolver;

impl Solver for BakpSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Bakp
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.x() {
            MatrixRef::Dense(x) => Ok(solver::solve_bakp(x, p.y(), opts)),
            MatrixRef::SparseCsc(s) => Ok(sparse::solve::solve_bakp_csc(s, p.y(), opts)),
            MatrixRef::Streamed(_) => Err(streamed_unsupported("bakp")),
        }
    }
}

/// Column-partitioned block-parallel SolveBak: concurrent per-block inner
/// sweeps on the [`crate::parallel`] pool, merged every sweep.
/// `opts.threads` sets the block count; 1 is serial Algorithm 1.
pub struct BakParSolver;

impl Solver for BakParSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::BakPar
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.x() {
            MatrixRef::Dense(x) => Ok(crate::parallel::solve_bak_par(x, p.y(), opts)),
            MatrixRef::SparseCsc(s) => {
                Ok(crate::parallel::solve_bak_par_csc(s, p.y(), opts))
            }
            MatrixRef::Streamed(_) => Err(streamed_unsupported("bak_par")),
        }
    }
}

/// Row-partitioned parallel randomized Kaczmarz (averaging sync) on the
/// [`crate::parallel`] pool. `opts.threads` sets the block count.
pub struct KaczmarzParSolver;

impl Solver for KaczmarzParSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::KaczmarzPar
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.x() {
            MatrixRef::Dense(x) => Ok(crate::parallel::solve_kaczmarz_par(x, p.y(), opts)),
            MatrixRef::SparseCsc(s) => {
                // Row actions want CSR, as in the serial Kaczmarz adapter.
                let csr = s.to_csr();
                Ok(crate::parallel::solve_kaczmarz_par_csr(&csr, p.y(), opts))
            }
            MatrixRef::Streamed(_) => Err(streamed_unsupported("kaczmarz_par")),
        }
    }
}

/// Multi-RHS SolveBak, run with a single right-hand side. The coordinator
/// uses the underlying [`solver::solve_bak_multi`] directly to amortise
/// whole batches; this adapter makes the kind addressable standalone.
pub struct BakMultiSolver;

impl Solver for BakMultiSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::BakMulti
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        if let MatrixRef::Streamed(s) = p.x() {
            let mut out =
                crate::stream::solve_bak_multi_stream(s, &[p.y().to_vec()], opts)?;
            return out.reports.pop().ok_or_else(|| SolverError::Backend {
                backend: "bak_multi".into(),
                reason: "no report produced".into(),
            });
        }
        let x = dense_or_warn(p, "bak_multi")?;
        let mut reports = solver::solve_bak_multi(&x, &[p.y().to_vec()], opts);
        reports.pop().ok_or_else(|| SolverError::Backend {
            backend: "bak_multi".into(),
            reason: "no report produced".into(),
        })
    }
}

/// Randomized Kaczmarz — row-action dual of SolveBak.
pub struct KaczmarzSolver;

impl Solver for KaczmarzSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Kaczmarz
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match p.x() {
            MatrixRef::Dense(x) => Ok(solver::solve_kaczmarz(x, p.y(), opts)),
            MatrixRef::SparseCsc(s) => {
                // Row actions want CSR; the O(nnz) counting transpose is
                // far cheaper than densifying.
                let csr = s.to_csr();
                Ok(sparse::solve::solve_kaczmarz_csr(&csr, p.y(), opts))
            }
            MatrixRef::Streamed(s) => {
                crate::stream::solve_kaczmarz_stream(s, p.y(), opts).map(|r| r.report)
            }
        }
    }
}

/// Greedy Gauss-Southwell column selection.
pub struct GaussSouthwellSolver;

impl Solver for GaussSouthwellSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::GaussSouthwell
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        let x = dense_or_warn(p, "gauss_southwell")?;
        Ok(solver::solve_gauss_southwell(&x, p.y(), opts))
    }
}

/// Householder-QR least squares (tall) / minimum-norm (wide) — the
/// paper's "LAPACK" comparator.
pub struct QrSolver;

impl Solver for QrSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Qr
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts; // direct method: convergence knobs don't apply
        self.capabilities().check(p.obs(), p.vars())?;
        let x = dense_or_warn(p, "qr")?;
        let a = baselines::qr::lstsq_qr(&x, p.y())?;
        Ok(report_from_coefficients(&x, p.y(), a))
    }
}

/// Normal equations via Cholesky (tall, full column rank).
pub struct CholeskySolver;

impl Solver for CholeskySolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Cholesky
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts;
        self.capabilities().check(p.obs(), p.vars())?;
        let x = dense_or_warn(p, "cholesky")?;
        let a = baselines::cholesky::solve_normal_equations(&x, p.y(), 0.0)?;
        Ok(report_from_coefficients(&x, p.y(), a))
    }
}

/// Gaussian elimination with partial pivoting (square systems only).
pub struct GaussSolver;

impl Solver for GaussSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Gauss
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        let _ = opts;
        self.capabilities().check(p.obs(), p.vars())?;
        let x = dense_or_warn(p, "gauss")?;
        let a = baselines::gauss::gauss_solve(&x, p.y())?;
        Ok(report_from_coefficients(&x, p.y(), a))
    }
}

/// Conjugate gradient on the normal equations.
pub struct CglsSolver;

impl Solver for CglsSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Cgls
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        let rep = match p.x() {
            MatrixRef::Dense(x) => baselines::cgls::cgls_solve_probed(
                x,
                p.y(),
                opts.max_sweeps,
                opts.tol,
                &opts.probe,
            ),
            MatrixRef::SparseCsc(s) => sparse::solve::cgls_csc_probed(
                s,
                p.y(),
                opts.max_sweeps,
                opts.tol,
                &opts.probe,
            ),
            MatrixRef::Streamed(_) => return Err(streamed_unsupported("cgls")),
        };
        let e = residual_ref(p.x(), p.y(), &rep.a);
        Ok(SolveReport {
            a: rep.a,
            e,
            history: rep.history,
            y_norm_sq: blas1::sum_sq_f64(p.y()),
            sweeps: rep.iterations,
            stop: if rep.converged {
                StopReason::Converged
            } else {
                StopReason::MaxSweeps
            },
        })
    }
}

/// AOT-compiled sweep artifacts executed through the PJRT engine.
///
/// [`PjrtSolver::detached`] (what the [`super::registry`] hands out) has
/// no engine and reports [`SolverError::Unavailable`]; services that
/// loaded artifacts wrap their engine via [`PjrtSolver::with_engine`].
pub struct PjrtSolver {
    engine: Option<Arc<Engine>>,
}

impl PjrtSolver {
    /// No engine attached; `solve` returns `Unavailable`.
    pub fn detached() -> Self {
        Self { engine: None }
    }

    /// Execute through a loaded engine.
    pub fn with_engine(engine: Arc<Engine>) -> Self {
        Self { engine: Some(engine) }
    }
}

impl Solver for PjrtSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Pjrt
    }

    fn capabilities(&self) -> Capabilities {
        self.kind().capabilities().expect("concrete kind")
    }

    fn solve(
        &self,
        p: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError> {
        self.capabilities().check(p.obs(), p.vars())?;
        match &self.engine {
            None => Err(SolverError::Unavailable {
                backend: "pjrt".into(),
                reason: "no engine attached (load artifacts and use with_engine)".into(),
            }),
            Some(eng) => {
                // Densify only once an engine exists — detached solves
                // must stay O(1).
                let x = dense_or_warn(p, "pjrt")?;
                eng.solve(&x, p.y(), opts, ArtifactKind::BakpSweep)
                    .map(|o| o.report)
                    .map_err(|e| SolverError::Backend {
                        backend: "pjrt".into(),
                        reason: e.to_string(),
                    })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    #[test]
    fn bak_solver_matches_free_function() {
        let (x, y, _) = planted(700, 150, 20);
        let opts = SolveOptions::accurate();
        let p = Problem::new(&x, &y).unwrap();
        let via_trait = BakSolver.solve(&p, &opts).unwrap();
        let direct = solver::solve_bak(&x, &y, &opts);
        assert_eq!(via_trait.a, direct.a);
    }

    #[test]
    fn bak_warm_start_honoured() {
        let (x, y, a_true) = planted(701, 200, 15);
        let opts = SolveOptions::builder().max_sweeps(1).tol(0.0).build();
        let p = Problem::new(&x, &y).unwrap();
        // One sweep from the truth stays at the truth (residual ~ 0).
        let warm = p.with_warm_start(&a_true).unwrap();
        let rep = BakSolver.solve(&warm, &opts).unwrap();
        assert!(rep.rel_residual() < 1e-4, "rel={}", rep.rel_residual());
        // One cold sweep is measurably worse than starting at the truth.
        let cold = BakSolver.solve(&p, &opts).unwrap();
        assert!(cold.rel_residual() > rep.rel_residual());
    }

    #[test]
    fn gauss_rejects_non_square() {
        let (x, y, _) = planted(702, 30, 10);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            GaussSolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::NeedsSquare { obs: 30, vars: 10 })
        ));
    }

    #[test]
    fn cholesky_rejects_wide() {
        let (x, y, _) = planted(703, 10, 30);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            CholeskySolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::Shape(_))
        ));
    }

    #[test]
    fn qr_rank_deficiency_is_typed_error() {
        let mut rng = Rng::seed(704);
        let mut x = Mat::randn(&mut rng, 12, 3);
        let c0 = x.col(0).to_vec();
        x.col_mut(1).copy_from_slice(&c0);
        let y: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            QrSolver.solve(&p, &SolveOptions::default()),
            Err(SolverError::RankDeficient { .. })
        ));
    }

    #[test]
    fn cgls_report_has_exit_invariant() {
        let (x, y, a_true) = planted(705, 120, 10);
        let p = Problem::new(&x, &y).unwrap();
        let opts = SolveOptions::builder().max_sweeps(100).tol(1e-8).build();
        let rep = CglsSolver.solve(&p, &opts).unwrap();
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
        let fresh = crate::linalg::residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-4);
        }
    }

    #[test]
    fn detached_pjrt_is_unavailable() {
        let (x, y, _) = planted(706, 20, 4);
        let p = Problem::new(&x, &y).unwrap();
        assert!(matches!(
            PjrtSolver::detached().solve(&p, &SolveOptions::default()),
            Err(SolverError::Unavailable { .. })
        ));
    }

    fn planted_sparse(
        seed: u64,
        obs: usize,
        vars: usize,
    ) -> (crate::sparse::CscMat, Vec<f32>, Vec<f32>) {
        let w = crate::bench::workload::SparseWorkload::uniform(
            crate::bench::workload::WorkloadSpec::new(obs, vars, seed),
            0.15,
        );
        (w.x, w.y, w.a_true)
    }

    #[test]
    fn sparse_native_solvers_match_their_densified_run() {
        let (x, y, _) = planted_sparse(710, 150, 18);
        let dense = x.to_dense();
        let opts = SolveOptions::builder().max_sweeps(4).tol(0.0).build();
        for kind in [SolverKind::Bak, SolverKind::Bakp, SolverKind::Kaczmarz] {
            let solver = super::super::solver_for(kind).unwrap();
            let ps = Problem::new_sparse(&x, &y).unwrap();
            let pd = Problem::new(&dense, &y).unwrap();
            let rs = solver.solve(&ps, &opts).unwrap();
            let rd = solver.solve(&pd, &opts).unwrap();
            for (s, d) in rs.a.iter().zip(&rd.a) {
                assert!((s - d).abs() < 1e-3, "{kind}: sparse {s} vs dense {d}");
            }
        }
    }

    #[test]
    fn cgls_solves_sparse_natively() {
        let (x, y, a_true) = planted_sparse(711, 200, 15);
        let p = Problem::new_sparse(&x, &y).unwrap();
        let opts = SolveOptions::builder().max_sweeps(100).tol(1e-8).build();
        let rep = CglsSolver.solve(&p, &opts).unwrap();
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
        // Exit invariant holds against the sparse matrix.
        let fresh = residual_ref(p.x(), &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-4);
        }
    }

    #[test]
    fn dense_only_solver_answers_sparse_via_densification() {
        let (x, y, a_true) = planted_sparse(712, 60, 12);
        let p = Problem::new_sparse(&x, &y).unwrap();
        let rep = QrSolver.solve(&p, &SolveOptions::default()).unwrap();
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn bak_par_solver_matches_free_function() {
        let (x, y, _) = planted(707, 200, 24);
        let opts = SolveOptions::builder().max_sweeps(3).tol(0.0).threads(4).build();
        let p = Problem::new(&x, &y).unwrap();
        let via_trait = BakParSolver.solve(&p, &opts).unwrap();
        let direct = crate::parallel::solve_bak_par(&x, &y, &opts);
        assert_eq!(via_trait.a, direct.a);
    }

    #[test]
    fn kaczmarz_par_solver_runs_sparse_natively() {
        let (x, y, a_true) = planted_sparse(708, 240, 12);
        let p = Problem::new_sparse(&x, &y).unwrap();
        let opts = SolveOptions::builder()
            .max_sweeps(2000)
            .tol(1e-4)
            .threads(2)
            .build();
        let rep = KaczmarzParSolver.solve(&p, &opts).unwrap();
        assert!(rep.rel_residual() < 1e-3, "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 0.05);
    }

    fn planted_streamed(
        seed: u64,
        obs: usize,
        vars: usize,
        chunk: usize,
    ) -> (Mat, Vec<f32>, crate::stream::StreamedMatrix, std::path::PathBuf) {
        let (x, y, _) = planted(seed, obs, vars);
        let path = crate::stream::temp_chunk_path("backend");
        crate::stream::write_chunked_dense(&x, chunk, &path).unwrap();
        let s = crate::stream::StreamedMatrix::open(&path).unwrap();
        (x, y, s, path)
    }

    #[test]
    fn streaming_trio_solves_file_backed_problems() {
        let (x, y, s, path) = planted_streamed(720, 120, 16, 5);
        let opts = SolveOptions::builder().max_sweeps(30).tol(1e-6).build();
        let p = Problem::new_streamed(&s, &y).unwrap();
        // bak: bit-identical to the in-memory trait run.
        let dense_p = Problem::new(&x, &y).unwrap();
        let via_stream = BakSolver.solve(&p, &opts).unwrap();
        let via_dense = BakSolver.solve(&dense_p, &opts).unwrap();
        assert_eq!(via_stream.a, via_dense.a);
        // kaczmarz and bak_multi answer too.
        assert!(KaczmarzSolver.solve(&p, &opts).unwrap().a.iter().all(|v| v.is_finite()));
        let multi = BakMultiSolver.solve(&p, &opts).unwrap();
        assert_eq!(multi.a, BakMultiSolver.solve(&dense_p, &opts).unwrap().a);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn non_streaming_backends_reject_file_backed_problems() {
        let (_, y, s, path) = planted_streamed(721, 30, 10, 4);
        let p = Problem::new_streamed(&s, &y).unwrap();
        let opts = SolveOptions::default();
        for kind in [
            SolverKind::Bakp,
            SolverKind::BakPar,
            SolverKind::KaczmarzPar,
            SolverKind::GaussSouthwell,
            SolverKind::Qr,
            SolverKind::Cholesky,
            SolverKind::Cgls,
        ] {
            let err = super::super::solver_for(kind).unwrap().solve(&p, &opts).unwrap_err();
            assert!(
                matches!(err, SolverError::Unavailable { .. }),
                "{kind}: expected a typed streaming refusal, got {err:?}"
            );
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_warm_start_matches_dense_warm_start() {
        let (x, y, s, path) = planted_streamed(722, 60, 8, 3);
        let a0 = vec![0.5f32; 8];
        let opts = SolveOptions::builder().max_sweeps(5).tol(0.0).build();
        let ps = Problem::new_streamed(&s, &y).unwrap().with_warm_start(&a0).unwrap();
        let pd = Problem::new(&x, &y).unwrap().with_warm_start(&a0).unwrap();
        let rs = BakSolver.solve(&ps, &opts).unwrap();
        let rd = BakSolver.solve(&pd, &opts).unwrap();
        assert_eq!(rs.a, rd.a, "streamed warm start diverges from dense");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn warm_state_resumes_from_stored_residual() {
        let (x, y, _) = planted(723, 100, 10);
        let opts = SolveOptions::builder().max_sweeps(3).tol(0.0).build();
        let p = Problem::new(&x, &y).unwrap();
        // Run 3 sweeps, capture (a, e), resume for 3 more via warm state.
        let first = BakSolver.solve(&p, &opts).unwrap();
        let resumed = BakSolver
            .solve(&p.with_warm_state(&first.a, &first.e).unwrap(), &opts)
            .unwrap();
        // One uninterrupted 6-sweep run must match bit-for-bit.
        let full = BakSolver
            .solve(&p, &SolveOptions::builder().max_sweeps(6).tol(0.0).build())
            .unwrap();
        assert_eq!(resumed.a, full.a, "resume is not bit-identical");
        assert_eq!(resumed.e, full.e);
    }

    #[test]
    fn bak_sparse_warm_start_honoured() {
        let (x, y, a_true) = planted_sparse(713, 180, 12);
        let opts = SolveOptions::builder().max_sweeps(1).tol(0.0).build();
        let p = Problem::new_sparse(&x, &y).unwrap();
        let warm = p.with_warm_start(&a_true).unwrap();
        let rep = BakSolver.solve(&warm, &opts).unwrap();
        assert!(rep.rel_residual() < 1e-4, "rel={}", rep.rel_residual());
        let cold = BakSolver.solve(&p, &opts).unwrap();
        assert!(cold.rel_residual() > rep.rel_residual());
    }
}
