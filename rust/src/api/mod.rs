//! The first-class solver API: one `Problem`, one `Solver` trait, one
//! `SolverKind` namespace, one typed `SolverError`.
//!
//! Every layer of the crate used to invent its own way to name and invoke
//! an algorithm (the bench harness's `Method`, the coordinator's
//! `Backend`, raw string matching in the CLI, and six free functions with
//! incompatible signatures). This module is the single dispatch surface
//! they all route through now:
//!
//! * [`Problem`] — a borrowed, validated `(X, y, warm-start)` triple.
//! * [`Solver`] — `solve(&Problem, &SolveOptions) -> Result<SolveReport,
//!   SolverError>` plus `name()` and `capabilities()`.
//! * [`SolverKind`] — the canonical enum of every implementation, with
//!   `FromStr`/`Display` for CLI/wire use and [`registry`]/[`solver_for`]
//!   constructors.
//! * [`SolverError`] — typed failures replacing ad-hoc `Result<_, String>`
//!   and panic paths.
//!
//! The free functions (`solve_bak`, `lstsq_qr`, `cgls_solve`, …) remain as
//! thin stable wrappers; the trait impls in [`backends`] delegate to them,
//! so existing callers keep compiling unchanged.
//!
//! ## Capability matrix
//!
//! | kind              | supports_wide | iterative | needs_square | warm_start | supports_sparse | parallel | streaming | probe | sharding |
//! |-------------------|---------------|-----------|--------------|------------|-----------------|----------|-----------|-------|----------|
//! | `bak`             | yes           | yes       | no           | yes        | yes (CSC)       | no       | yes       | yes   | no       |
//! | `bakp`            | yes           | yes       | no           | no         | yes (CSC)       | in-block | no        | yes   | no       |
//! | `bak_par`         | yes           | yes       | no           | no         | yes (CSC)       | yes      | no        | yes   | yes      |
//! | `bak_multi`       | yes           | yes       | no           | no         | no (densifies)  | no       | yes       | yes   | no       |
//! | `kaczmarz`        | yes           | yes       | no           | no         | yes (CSR)       | no       | yes       | yes   | no       |
//! | `kaczmarz_par`    | yes           | yes       | no           | no         | yes (CSR)       | yes      | no        | yes   | yes      |
//! | `gauss_southwell` | yes           | yes       | no           | no         | no (densifies)  | no       | no        | yes   | no       |
//! | `qr`              | yes (min-norm)| no        | no           | no         | no (densifies)  | no       | no        | no    | no       |
//! | `cholesky`        | no            | no        | no           | no         | no (densifies)  | no       | no        | no    | no       |
//! | `gauss`           | no            | no        | yes          | no         | no (densifies)  | no       | no        | no    | no       |
//! | `cgls`            | yes           | yes       | no           | no         | yes (CSC)       | no       | no        | yes   | no       |
//! | `pjrt`            | yes (bucketed)| yes       | no           | no         | no (densifies)  | no       | no        | no    | no       |
//!
//! The `parallel` column is the `supports_parallel` capability: the
//! backend scales with [`crate::solver::SolveOptions::threads`]
//! (`bak_par`/`kaczmarz_par` run whole block-partitioned sweeps on the
//! [`crate::parallel`] layer; `bakp` threads its in-block phases). The
//! coordinator's router prefers these variants when a request asks for
//! `threads > 1`.
//!
//! Sparse problems ([`Problem::new_sparse`]) run natively on the kinds
//! whose `supports_sparse` is true; every other kind transparently
//! densifies the matrix (with a logged warning — and a `densified_jobs`
//! metric when it happens inside the coordinator) so *all* registered
//! solvers answer sparse requests.
//!
//! The `streaming` column is `supports_streaming`: the backend runs
//! file-backed problems ([`Problem::new_streamed`]) out-of-core, reading
//! the matrix in chunks (see [`crate::stream`]). Unlike sparse, there is
//! NO transparent fallback — densifying a matrix that was put on disk
//! precisely because it may not fit in RAM would defeat the point, so
//! non-streaming backends return a typed [`SolverError`] instead.
//!
//! The `probe` column is `supports_probe`: the backend calls the
//! [`crate::obs::SolveProbe`] attached via
//! [`crate::solver::SolveOptions::probe`] once per residual check, so
//! traced requests get a live convergence trajectory. Direct methods (qr,
//! cholesky, gauss) and the opaque PJRT artifact path have no per-sweep
//! residual to report; they ignore the probe and their trajectory is the
//! single exit residual.
//!
//! The `sharding` column is `supports_sharding`: the backend's
//! block-partitioned sweep math distributes across contiguous row shards
//! with a mass-weighted merge at every sync round, which is exactly what
//! the [`crate::cluster`] layer exploits to run one solve across many
//! worker processes. Only the block-parallel pair (`bak_par`,
//! `kaczmarz_par`) qualifies — their per-block iterates are already
//! independent between syncs — and the cluster coordinator dispatches
//! shards only to kinds advertising this flag.

pub mod backends;
pub mod kind;

pub use backends::PjrtSolver;
pub use kind::{registry, solver_for, SolverKind};

use std::borrow::Cow;

use crate::linalg::{blas1, Mat};
use crate::solver::{SolveOptions, SolveReport, StopReason};
use crate::sparse::CscMat;
use crate::stream::StreamedMatrix;

/// Typed solver failure. Replaces the crate's previous mix of
/// `Result<_, String>` and `expect(...)` panic paths.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverError {
    /// Dimensions are inconsistent or unsupported (details in message).
    Shape(String),
    /// An input slice contains NaN/Inf.
    NonFinite {
        /// Which input ("x", "y", "warm start").
        what: &'static str,
    },
    /// The solver only accepts square systems (e.g. Gaussian elimination).
    NeedsSquare { obs: usize, vars: usize },
    /// The matrix is numerically rank-deficient at the given column.
    RankDeficient { column: usize },
    /// The backend exists but cannot run here (e.g. PJRT without an
    /// engine/artifacts).
    Unavailable { backend: String, reason: String },
    /// No solver is registered under this name/kind.
    UnknownKind(String),
    /// The backend started but failed mid-solve.
    Backend { backend: String, reason: String },
    /// Service-level failure (coordinator shut down, reply channel lost).
    Service(String),
    /// A request or option is malformed (inconsistent COO triplet lengths,
    /// an unsupported option combination, a bad file path, …). Unlike
    /// [`SolverError::Shape`] the *dimensions* may be fine — the payload
    /// itself is self-contradictory.
    InvalidInput(String),
    /// The request's deadline expired before the solve finished. Carries
    /// the best-so-far coefficients and the relative residual they
    /// achieve — the BAK family's partial answer is always usable.
    DeadlineExceeded {
        /// Best-so-far coefficient vector at cancellation (vars; all
        /// zeros when the deadline expired before the first sweep).
        best: Vec<f32>,
        /// Relative residual achieved by `best`.
        rel_residual: f64,
        /// Sweeps completed before the deadline hit.
        sweeps: usize,
    },
    /// Admission control shed the request: the service is saturated.
    Overloaded {
        /// Suggested client backoff before retrying.
        retry_after_ms: u64,
    },
    /// The request used a protocol feature this build does not speak
    /// (unknown wire field, unknown command, unsupported protocol
    /// version).
    Unsupported(String),
    /// A stored chunk failed its integrity check: the CRC32 recorded when
    /// the `.sbck` file was written does not match the bytes read back.
    /// The data is damaged (bit rot, truncated write, bad disk) — retrying
    /// the solve will not help until the file is regenerated.
    CorruptData {
        /// Zero-based index of the damaged chunk.
        chunk: usize,
        /// CRC32 stored in the file at write time.
        expected: u32,
        /// CRC32 computed over the bytes actually read.
        actual: u32,
    },
    /// The iterate became numerical garbage mid-solve (residual went
    /// NaN/Inf, or the watchdog saw sustained divergence) and the solver
    /// stopped instead of burning the remaining sweeps on noise.
    NumericalBreakdown {
        /// What the watchdog/solver observed ("residual is NaN", …).
        detail: String,
        /// Sweeps completed before the breakdown was detected.
        sweeps: usize,
    },
}

impl std::fmt::Display for SolverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverError::Shape(s) => write!(f, "shape error: {s}"),
            SolverError::NonFinite { what } => {
                write!(f, "{what} contains non-finite values")
            }
            SolverError::NeedsSquare { obs, vars } => {
                write!(f, "solver needs a square system, got {obs}x{vars}")
            }
            SolverError::RankDeficient { column } => {
                write!(f, "rank deficient at column {column}")
            }
            SolverError::Unavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            SolverError::UnknownKind(s) => write!(f, "unknown solver kind '{s}'"),
            SolverError::Backend { backend, reason } => {
                write!(f, "backend '{backend}' failed: {reason}")
            }
            SolverError::Service(s) => write!(f, "service error: {s}"),
            SolverError::InvalidInput(s) => write!(f, "invalid input: {s}"),
            SolverError::DeadlineExceeded { rel_residual, sweeps, .. } => write!(
                f,
                "deadline exceeded after {sweeps} sweeps (best rel_residual {rel_residual:.3e})"
            ),
            SolverError::Overloaded { retry_after_ms } => {
                write!(f, "service overloaded, retry after {retry_after_ms}ms")
            }
            SolverError::Unsupported(s) => write!(f, "unsupported: {s}"),
            SolverError::CorruptData { chunk, expected, actual } => write!(
                f,
                "corrupt data: chunk {chunk} stored crc32 {expected:#010x} but bytes hash to {actual:#010x}"
            ),
            SolverError::NumericalBreakdown { detail, sweeps } => {
                write!(f, "numerical breakdown after {sweeps} sweeps: {detail}")
            }
        }
    }
}

impl std::error::Error for SolverError {}

impl From<crate::baselines::qr::SolveError> for SolverError {
    fn from(e: crate::baselines::qr::SolveError) -> Self {
        match e {
            crate::baselines::qr::SolveError::RankDeficient(j) => {
                SolverError::RankDeficient { column: j }
            }
            crate::baselines::qr::SolveError::Shape(s) => SolverError::Shape(s),
        }
    }
}

/// A borrowed view of the system matrix: dense col-major [`Mat`],
/// compressed sparse column [`CscMat`], or a file-backed
/// [`StreamedMatrix`] whose payload stays on disk.
///
/// This is the type [`Problem`] carries, so every [`Solver`] sees one
/// dispatch surface for all representations. Solvers with native sparse
/// paths match on it; dense-only solvers call [`MatrixRef::to_dense`]
/// (borrowing when already dense, materialising O(obs*vars) otherwise).
/// Backends without `supports_streaming` must NOT densify a `Streamed`
/// matrix — the whole point is that it may not fit in RAM — they return a
/// typed [`SolverError`] instead (see [`backends`]).
#[derive(Clone, Copy)]
pub enum MatrixRef<'a> {
    /// Dense column-major storage.
    Dense(&'a Mat),
    /// Compressed sparse column storage.
    SparseCsc(&'a CscMat),
    /// On-disk chunked column-major storage (see [`crate::stream`]).
    Streamed(&'a StreamedMatrix),
}

impl<'a> MatrixRef<'a> {
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            MatrixRef::Dense(m) => m.rows(),
            MatrixRef::SparseCsc(s) => s.rows(),
            MatrixRef::Streamed(s) => s.rows(),
        }
    }

    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            MatrixRef::Dense(m) => m.cols(),
            MatrixRef::SparseCsc(s) => s.cols(),
            MatrixRef::Streamed(s) => s.cols(),
        }
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    /// Stored entries: `rows*cols` for dense/streamed, `nnz` for sparse.
    pub fn nnz(&self) -> usize {
        match self {
            MatrixRef::Dense(m) => m.rows() * m.cols(),
            MatrixRef::SparseCsc(s) => s.nnz(),
            MatrixRef::Streamed(s) => s.rows() * s.cols(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, MatrixRef::SparseCsc(_))
    }

    /// True when the matrix payload lives on disk ([`crate::stream`]).
    pub fn is_streamed(&self) -> bool {
        matches!(self, MatrixRef::Streamed(_))
    }

    /// Dense view: borrows when already dense, materialises (O(rows*cols))
    /// otherwise. Callers that care about the cost should check
    /// [`MatrixRef::is_sparse`] / [`MatrixRef::is_streamed`] first —
    /// backends never call this on a streamed matrix (it defeats
    /// out-of-core and panics if the file read fails); the [`backends`]
    /// layer returns a typed error before reaching here.
    pub fn to_dense(&self) -> Cow<'a, Mat> {
        match *self {
            MatrixRef::Dense(m) => Cow::Borrowed(m),
            MatrixRef::SparseCsc(s) => Cow::Owned(s.to_dense()),
            MatrixRef::Streamed(s) => {
                Cow::Owned(s.to_mat().expect("read streamed matrix into RAM"))
            }
        }
    }

    /// y = X a (O(nnz) on sparse storage; one disk pass on streamed).
    pub fn matvec(&self, a: &[f32]) -> Vec<f32> {
        match self {
            MatrixRef::Dense(m) => m.matvec(a),
            MatrixRef::SparseCsc(s) => s.matvec(a),
            MatrixRef::Streamed(s) => s.matvec(a),
        }
    }

    /// out = Xᵀ v (O(nnz) on sparse storage; one disk pass on streamed).
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        match self {
            MatrixRef::Dense(m) => m.matvec_t(v),
            MatrixRef::SparseCsc(s) => s.matvec_t(v),
            MatrixRef::Streamed(s) => s.matvec_t(v),
        }
    }

    /// <x_j, x_j> for every column.
    pub fn colnorms_sq(&self) -> Vec<f32> {
        match self {
            MatrixRef::Dense(m) => m.colnorms_sq(),
            MatrixRef::SparseCsc(s) => s.colnorms_sq(),
            MatrixRef::Streamed(s) => s.colnorms_sq(),
        }
    }
}

/// Residual e = y - X a against either representation.
pub fn residual_ref(x: MatrixRef<'_>, y: &[f32], a: &[f32]) -> Vec<f32> {
    let xa = x.matvec(a);
    y.iter().zip(&xa).map(|(&yi, &xi)| yi - xi).collect()
}

/// A validated least-squares problem: minimise `||y - X a||` (borrowed
/// views; construction checks shapes and scans for NaN/Inf so solvers can
/// assume clean inputs). The matrix side is a [`MatrixRef`] — dense or
/// sparse CSC — so one `Problem` type serves both workload classes.
#[derive(Clone, Copy)]
pub struct Problem<'a> {
    x: MatrixRef<'a>,
    y: &'a [f32],
    warm: Option<&'a [f32]>,
    warm_e: Option<&'a [f32]>,
}

impl<'a> Problem<'a> {
    /// Validate and wrap a dense `(X, y)`.
    pub fn new(x: &'a Mat, y: &'a [f32]) -> Result<Self, SolverError> {
        Self::validate_matrix(x)?;
        Self::prevalidated(x, y)
    }

    /// Validate and wrap a sparse `(X, y)`.
    pub fn new_sparse(x: &'a CscMat, y: &'a [f32]) -> Result<Self, SolverError> {
        Self::validate_sparse_matrix(x)?;
        Self::prevalidated_sparse(x, y)
    }

    /// Wrap a file-backed `(X, y)`. The payload stays on disk, so only the
    /// header-derived shape and the O(obs) y side are validated — no
    /// finite-scan of X (that would be a full read of a matrix chosen to
    /// be bigger than RAM). Solve it through a backend whose
    /// [`Capabilities::supports_streaming`] is true.
    pub fn new_streamed(x: &'a StreamedMatrix, y: &'a [f32]) -> Result<Self, SolverError> {
        Self::prevalidated_ref(MatrixRef::Streamed(x), y)
    }

    /// Matrix-side validation only: non-empty and finite. `O(obs*vars)`.
    pub fn validate_matrix(x: &Mat) -> Result<(), SolverError> {
        let (obs, vars) = x.shape();
        if obs == 0 || vars == 0 {
            return Err(SolverError::Shape(format!("empty system {obs}x{vars}")));
        }
        if !x.as_slice().iter().all(|v| v.is_finite()) {
            return Err(SolverError::NonFinite { what: "x" });
        }
        Ok(())
    }

    /// Sparse matrix-side validation: non-empty shape and finite stored
    /// values. `O(nnz)`.
    pub fn validate_sparse_matrix(x: &CscMat) -> Result<(), SolverError> {
        let (obs, vars) = x.shape();
        if obs == 0 || vars == 0 {
            return Err(SolverError::Shape(format!("empty system {obs}x{vars}")));
        }
        if !x.values().iter().all(|v| v.is_finite()) {
            return Err(SolverError::NonFinite { what: "x" });
        }
        Ok(())
    }

    /// Like [`Problem::new`] but skips the `O(obs*vars)` finite-scan of
    /// `x` — for callers that ran [`Problem::validate_matrix`] once and
    /// construct many problems against the same shared matrix (the
    /// coordinator's batch path). Still checks the `O(obs)` y side.
    pub fn prevalidated(x: &'a Mat, y: &'a [f32]) -> Result<Self, SolverError> {
        Self::prevalidated_ref(MatrixRef::Dense(x), y)
    }

    /// Sparse counterpart of [`Problem::prevalidated`] (pair it with
    /// [`Problem::validate_sparse_matrix`]).
    pub fn prevalidated_sparse(x: &'a CscMat, y: &'a [f32]) -> Result<Self, SolverError> {
        Self::prevalidated_ref(MatrixRef::SparseCsc(x), y)
    }

    /// Shared y-side validation over either representation.
    pub fn prevalidated_ref(x: MatrixRef<'a>, y: &'a [f32]) -> Result<Self, SolverError> {
        let (obs, vars) = x.shape();
        if obs == 0 || vars == 0 {
            return Err(SolverError::Shape(format!("empty system {obs}x{vars}")));
        }
        if y.len() != obs {
            return Err(SolverError::Shape(format!(
                "y length {} != obs {obs}",
                y.len()
            )));
        }
        if !y.iter().all(|v| v.is_finite()) {
            return Err(SolverError::NonFinite { what: "y" });
        }
        Ok(Self { x, y, warm: None, warm_e: None })
    }

    /// Attach an initial coefficient guess (honoured by solvers whose
    /// [`Capabilities::warm_start`] is true; others ignore it).
    pub fn with_warm_start(mut self, a0: &'a [f32]) -> Result<Self, SolverError> {
        if a0.len() != self.vars() {
            return Err(SolverError::Shape(format!(
                "warm start length {} != vars {}",
                a0.len(),
                self.vars()
            )));
        }
        if !a0.iter().all(|v| v.is_finite()) {
            return Err(SolverError::NonFinite { what: "warm start" });
        }
        self.warm = Some(a0);
        Ok(self)
    }

    /// Attach a full warm *state*: coefficients plus the residual
    /// `e ≈ y - X a0` they had when captured. Solvers that track the
    /// residual incrementally (the BAK family) resume from the stored `e`
    /// instead of recomputing it — the property that makes a
    /// checkpoint-resumed solve bit-identical to one that never stopped,
    /// since the incrementally-updated residual drifts from the
    /// from-scratch product by accumulated f32 rounding. Backends without
    /// that resume path fall back to treating this as a plain warm start.
    pub fn with_warm_state(
        self,
        a0: &'a [f32],
        e0: &'a [f32],
    ) -> Result<Self, SolverError> {
        if e0.len() != self.obs() {
            return Err(SolverError::Shape(format!(
                "warm residual length {} != obs {}",
                e0.len(),
                self.obs()
            )));
        }
        if !e0.iter().all(|v| v.is_finite()) {
            return Err(SolverError::NonFinite { what: "warm residual" });
        }
        let mut p = self.with_warm_start(a0)?;
        p.warm_e = Some(e0);
        Ok(p)
    }

    /// The system matrix, dense or sparse.
    pub fn x(&self) -> MatrixRef<'a> {
        self.x
    }

    /// Dense view of the matrix: borrowed when the problem is dense,
    /// materialised (O(obs*vars)) when sparse. Backends without a native
    /// sparse path go through [`backends`]' warning-logged wrapper instead
    /// of calling this directly.
    pub fn dense_x(&self) -> Cow<'a, Mat> {
        self.x.to_dense()
    }

    /// True when the matrix is stored sparse.
    pub fn is_sparse(&self) -> bool {
        self.x.is_sparse()
    }

    /// True when the matrix payload lives on disk.
    pub fn is_streamed(&self) -> bool {
        self.x.is_streamed()
    }

    pub fn y(&self) -> &'a [f32] {
        self.y
    }

    pub fn warm_start(&self) -> Option<&'a [f32]> {
        self.warm
    }

    /// The checkpointed residual attached via [`Problem::with_warm_state`]
    /// (None for a plain [`Problem::with_warm_start`]).
    pub fn warm_residual(&self) -> Option<&'a [f32]> {
        self.warm_e
    }

    pub fn obs(&self) -> usize {
        self.x.rows()
    }

    pub fn vars(&self) -> usize {
        self.x.cols()
    }

    pub fn shape(&self) -> (usize, usize) {
        self.x.shape()
    }

    pub fn is_square(&self) -> bool {
        self.obs() == self.vars()
    }

    /// max(obs/vars, vars/obs): 1.0 = square, large = strongly non-square.
    pub fn aspect_ratio(&self) -> f64 {
        let (obs, vars) = self.shape();
        (obs as f64 / vars as f64).max(vars as f64 / obs as f64)
    }
}

/// What a solver can handle — routing and validation read these instead of
/// hard-coding per-backend knowledge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Accepts wide (vars > obs) systems.
    pub supports_wide: bool,
    /// Sweep/iteration-based (honours `max_sweeps`/`tol`); false = direct.
    pub iterative: bool,
    /// Only accepts square systems.
    pub needs_square: bool,
    /// Honours [`Problem::with_warm_start`].
    pub warm_start: bool,
    /// Runs sparse ([`MatrixRef::SparseCsc`]) problems natively in
    /// O(nnz) per sweep; false = the backend densifies sparse input
    /// (logged, and counted as `densified_jobs` by the coordinator).
    pub supports_sparse: bool,
    /// Scales with [`SolveOptions::threads`]: the backend runs
    /// block-parallel sweeps (or threaded in-block phases) on the
    /// [`crate::parallel`] layer. The router prefers such backends when a
    /// request asks for `threads > 1`.
    pub supports_parallel: bool,
    /// Runs file-backed ([`MatrixRef::Streamed`]) problems out-of-core via
    /// [`crate::stream`]; false = the backend returns a typed error for
    /// streamed input (it is never silently densified — see the module
    /// docs).
    pub supports_streaming: bool,
    /// Reports per-sweep residuals to the [`crate::obs::SolveProbe`]
    /// attached via [`SolveOptions::probe`]; false = the probe is ignored
    /// (direct methods and opaque artifact execution have no per-sweep
    /// residual).
    pub supports_probe: bool,
    /// The backend's block math allows contiguous row-shard distribution
    /// with the mass-weighted merge between sync rounds; the
    /// [`crate::cluster`] layer dispatches only to such kinds. True for
    /// the block-parallel pair (`bak_par`, `kaczmarz_par`) whose per-block
    /// iterates are independent between syncs.
    pub supports_sharding: bool,
}

impl Capabilities {
    /// Check a problem shape against these capabilities.
    pub fn check(&self, obs: usize, vars: usize) -> Result<(), SolverError> {
        if self.needs_square && obs != vars {
            return Err(SolverError::NeedsSquare { obs, vars });
        }
        if !self.supports_wide && vars > obs {
            return Err(SolverError::Shape(format!(
                "solver requires obs >= vars, got wide {obs}x{vars}"
            )));
        }
        Ok(())
    }
}

/// The uniform solver interface every implementation (paper algorithms,
/// baselines, PJRT execution) plugs into.
pub trait Solver: Send + Sync {
    /// The canonical kind of this implementation.
    fn kind(&self) -> SolverKind;

    /// Stable lowercase name (same string `SolverKind::from_str` accepts).
    fn name(&self) -> &'static str {
        self.kind().as_str()
    }

    /// What shapes/features this solver handles.
    fn capabilities(&self) -> Capabilities;

    /// Solve the problem. Implementations must return a typed error — no
    /// panicking on unsupported shapes or numerical breakdown.
    fn solve(
        &self,
        problem: &Problem<'_>,
        opts: &SolveOptions,
    ) -> Result<SolveReport, SolverError>;
}

/// Wrap a direct solver's coefficient vector in a [`SolveReport`]
/// (residual recomputed from scratch; `sweeps == 1`).
pub fn report_from_coefficients(x: &Mat, y: &[f32], a: Vec<f32>) -> SolveReport {
    let e = crate::linalg::residual(x, y, &a);
    let r2 = blas1::sum_sq_f64(&e);
    SolveReport {
        a,
        e,
        history: vec![r2],
        y_norm_sq: blas1::sum_sq_f64(y),
        sweeps: 1,
        stop: StopReason::Converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn problem_validates_shape() {
        let mut rng = Rng::seed(1);
        let x = Mat::randn(&mut rng, 8, 3);
        let y = vec![0.0f32; 7];
        assert!(matches!(Problem::new(&x, &y), Err(SolverError::Shape(_))));
        let y = vec![0.0f32; 8];
        assert!(Problem::new(&x, &y).is_ok());
    }

    #[test]
    fn problem_rejects_nan() {
        let mut rng = Rng::seed(2);
        let mut x = Mat::randn(&mut rng, 6, 2);
        let y = vec![0.0f32; 6];
        x.set(3, 1, f32::NAN);
        assert_eq!(
            Problem::new(&x, &y).unwrap_err(),
            SolverError::NonFinite { what: "x" }
        );
        let x = Mat::randn(&mut rng, 6, 2);
        let mut y = vec![0.0f32; 6];
        y[0] = f32::INFINITY;
        assert_eq!(
            Problem::new(&x, &y).unwrap_err(),
            SolverError::NonFinite { what: "y" }
        );
    }

    #[test]
    fn prevalidated_checks_y_but_trusts_x() {
        let mut rng = Rng::seed(5);
        let mut x = Mat::randn(&mut rng, 6, 2);
        x.set(0, 0, f32::NAN);
        assert!(Problem::validate_matrix(&x).is_err());
        // By contract prevalidated() skips the x scan...
        let y = vec![0.0f32; 6];
        assert!(Problem::prevalidated(&x, &y).is_ok());
        // ...but still rejects a bad y.
        let mut bad_y = y.clone();
        bad_y[2] = f32::NAN;
        assert_eq!(
            Problem::prevalidated(&x, &bad_y).unwrap_err(),
            SolverError::NonFinite { what: "y" }
        );
        assert!(Problem::prevalidated(&x, &[0.0; 5]).is_err());
    }

    #[test]
    fn problem_rejects_empty() {
        let x = Mat::zeros(0, 0);
        assert!(matches!(Problem::new(&x, &[]), Err(SolverError::Shape(_))));
    }

    #[test]
    fn warm_start_validated() {
        let mut rng = Rng::seed(3);
        let x = Mat::randn(&mut rng, 10, 4);
        let y = vec![1.0f32; 10];
        let p = Problem::new(&x, &y).unwrap();
        assert!(p.with_warm_start(&[0.0; 3]).is_err());
        let a0 = [0.5f32; 4];
        let p = p.with_warm_start(&a0).unwrap();
        assert_eq!(p.warm_start(), Some(&a0[..]));
        assert_eq!(p.warm_residual(), None);
    }

    #[test]
    fn warm_state_validated() {
        let mut rng = Rng::seed(8);
        let x = Mat::randn(&mut rng, 10, 4);
        let y = vec![1.0f32; 10];
        let p = Problem::new(&x, &y).unwrap();
        let a0 = [0.5f32; 4];
        let e0 = [0.25f32; 10];
        assert!(p.with_warm_state(&a0, &[0.0; 9]).is_err(), "short residual");
        assert!(p.with_warm_state(&[0.0; 3], &e0).is_err(), "short coeffs");
        let mut bad_e = e0;
        bad_e[4] = f32::NAN;
        assert_eq!(
            p.with_warm_state(&a0, &bad_e).unwrap_err(),
            SolverError::NonFinite { what: "warm residual" }
        );
        let p = p.with_warm_state(&a0, &e0).unwrap();
        assert_eq!(p.warm_start(), Some(&a0[..]));
        assert_eq!(p.warm_residual(), Some(&e0[..]));
    }

    #[test]
    fn aspect_ratio_symmetric() {
        let mut rng = Rng::seed(4);
        let tall = Mat::randn(&mut rng, 40, 10);
        let wide = Mat::randn(&mut rng, 10, 40);
        let yt = vec![0.0f32; 40];
        let yw = vec![0.0f32; 10];
        let pt = Problem::new(&tall, &yt).unwrap();
        let pw = Problem::new(&wide, &yw).unwrap();
        assert_eq!(pt.aspect_ratio(), pw.aspect_ratio());
        assert!(!pt.is_square());
    }

    #[test]
    fn capabilities_check() {
        let square_only = Capabilities {
            supports_wide: false,
            iterative: false,
            needs_square: true,
            warm_start: false,
            supports_sparse: false,
            supports_parallel: false,
            supports_streaming: false,
            supports_probe: false,
            supports_sharding: false,
        };
        assert!(square_only.check(5, 5).is_ok());
        assert!(matches!(
            square_only.check(6, 5),
            Err(SolverError::NeedsSquare { .. })
        ));
        let tall_only = Capabilities { needs_square: false, ..square_only };
        assert!(tall_only.check(6, 5).is_ok());
        assert!(matches!(tall_only.check(5, 6), Err(SolverError::Shape(_))));
    }

    #[test]
    fn qr_error_converts() {
        let e: SolverError = crate::baselines::qr::SolveError::RankDeficient(3).into();
        assert_eq!(e, SolverError::RankDeficient { column: 3 });
        assert!(e.to_string().contains("column 3"));
    }

    fn small_csc() -> crate::sparse::CscMat {
        let mut b = crate::sparse::CooBuilder::new(4, 2);
        b.push(0, 0, 1.0);
        b.push(2, 0, -2.0);
        b.push(1, 1, 3.0);
        b.to_csc()
    }

    #[test]
    fn sparse_problem_validates_and_reports_shape() {
        let x = small_csc();
        let y = vec![0.0f32; 4];
        let p = Problem::new_sparse(&x, &y).unwrap();
        assert!(p.is_sparse());
        assert_eq!(p.shape(), (4, 2));
        assert_eq!(p.x().nnz(), 3);
        assert!(matches!(
            Problem::new_sparse(&x, &[0.0; 3]),
            Err(SolverError::Shape(_))
        ));
    }

    #[test]
    fn sparse_problem_rejects_non_finite_values() {
        let mut b = crate::sparse::CooBuilder::new(3, 1);
        b.push(0, 0, f32::NAN);
        let x = b.to_csc();
        assert_eq!(
            Problem::new_sparse(&x, &[0.0; 3]).unwrap_err(),
            SolverError::NonFinite { what: "x" }
        );
    }

    #[test]
    fn dense_x_borrows_dense_and_materialises_sparse() {
        let mut rng = Rng::seed(6);
        let m = Mat::randn(&mut rng, 5, 3);
        let y = vec![0.0f32; 5];
        let p = Problem::new(&m, &y).unwrap();
        assert!(!p.is_sparse());
        assert!(matches!(p.dense_x(), std::borrow::Cow::Borrowed(_)));

        let x = small_csc();
        let ys = vec![0.0f32; 4];
        let ps = Problem::new_sparse(&x, &ys).unwrap();
        let dense = ps.dense_x();
        assert!(matches!(dense, std::borrow::Cow::Owned(_)));
        assert_eq!(*dense, x.to_dense());
    }

    #[test]
    fn streamed_problem_validates_and_reports_shape() {
        let mut rng = Rng::seed(7);
        let m = Mat::randn(&mut rng, 6, 4);
        let path = crate::stream::temp_chunk_path("api");
        crate::stream::write_chunked_dense(&m, 2, &path).unwrap();
        let s = StreamedMatrix::open(&path).unwrap();
        let y = vec![0.0f32; 6];
        let p = Problem::new_streamed(&s, &y).unwrap();
        assert!(p.is_streamed() && !p.is_sparse());
        assert_eq!(p.shape(), (6, 4));
        assert!(matches!(
            Problem::new_streamed(&s, &[0.0; 5]),
            Err(SolverError::Shape(_))
        ));
        // MatrixRef ops agree with the in-memory original.
        let sref = MatrixRef::Streamed(&s);
        assert!(sref.is_streamed());
        assert_eq!(sref.nnz(), 24);
        let a = [1.0f32, -2.0, 0.5, 3.0];
        assert_eq!(sref.matvec(&a), m.matvec(&a));
        assert_eq!(sref.colnorms_sq(), m.colnorms_sq());
        assert_eq!(*sref.to_dense(), m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn matrix_ref_ops_agree_across_representations() {
        let x = small_csc();
        let dense = x.to_dense();
        let sref = MatrixRef::SparseCsc(&x);
        let dref = MatrixRef::Dense(&dense);
        assert_eq!(sref.shape(), dref.shape());
        assert_eq!(sref.matvec(&[1.0, 2.0]), dref.matvec(&[1.0, 2.0]));
        assert_eq!(
            sref.matvec_t(&[1.0, 1.0, 1.0, 1.0]),
            dref.matvec_t(&[1.0, 1.0, 1.0, 1.0])
        );
        assert_eq!(sref.colnorms_sq(), dref.colnorms_sq());
        let a = [0.5f32, -1.0];
        let y = dense.matvec(&a);
        let e = residual_ref(sref, &y, &a);
        assert!(e.iter().all(|v| v.abs() < 1e-6));
    }
}
