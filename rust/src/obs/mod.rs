//! Observability primitives: solver convergence probes, request spans, and
//! the bounded ring of recent traces.
//!
//! Three pieces, designed so the disabled path costs nothing:
//!
//! * [`SolveProbe`] / [`ProbeHandle`] — a per-sweep callback carried inside
//!   [`crate::solver::SolveOptions`]. The handle is a newtype over
//!   `Option<Arc<dyn SolveProbe>>`: when no probe is attached the solver's
//!   per-sweep cost is a single `is_some()` branch — no allocation, no
//!   clock read, no virtual call. [`RingProbe`] is the standard
//!   implementation: a bounded, stride-downsampled residual trajectory.
//! * [`TraceCtx`] / [`SpanRecord`] — a per-request trace: a process-unique
//!   id ([`next_trace_id`]) plus a list of named spans with nanosecond
//!   monotonic timestamps relative to the trace epoch and optional parent
//!   links. Spans are appended under a short mutex hold (the coordinator
//!   records a handful per request, never per sweep).
//! * [`Telemetry`] / [`TraceRing`] — the per-request result (trace id,
//!   span timeline, residual trajectory), returned to traced clients under
//!   `"telemetry"` and retained in a bounded in-memory ring for the
//!   server's `{"cmd":"traces"}` endpoint.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{Json, ObjBuilder};

/// Per-sweep convergence observer. Implementations must be cheap and
/// lock-light: iterative solvers call [`SolveProbe::on_sweep`] once per
/// residual check (at most once per sweep) from the solving thread.
pub trait SolveProbe: Send + Sync {
    /// `sweep` is 1-based (the solver's `sweeps` counter at the check),
    /// `residual_norm` is `||y - Xa||` (not squared), `elapsed_ns` is time
    /// since the solve loop started.
    fn on_sweep(&self, sweep: usize, residual_norm: f64, elapsed_ns: u64);

    /// True when this probe wants [`SolveProbe::on_state`] calls. Solvers
    /// skip borrowing/cloning the iterate entirely when every attached
    /// probe returns false (the default), so state observation is strictly
    /// opt-in and existing probes keep their zero extra cost.
    fn wants_state(&self) -> bool {
        false
    }

    /// Full-state observation at a residual check: the iterate `a`, the
    /// maintained residual `e`, and the squared residual `r2`. Called only
    /// when [`SolveProbe::wants_state`] is true; used by
    /// [`crate::robust::checkpoint::CheckpointProbe`] to persist
    /// resumable state. Implementations must copy out what they need —
    /// the slices are borrowed from the live solve.
    fn on_state(&self, sweep: usize, a: &[f32], e: &[f32], r2: f64) {
        let _ = (sweep, a, e, r2);
    }
}

/// A cloneable, optionally-attached probe, carried by value inside
/// [`crate::solver::SolveOptions`].
///
/// The disabled default is the zero-overhead path the acceptance criteria
/// pin: `observe` is one branch on `Option::is_some`; the clock is read
/// and the sqrt taken only when a probe is attached.
#[derive(Clone, Default)]
pub struct ProbeHandle(Option<Arc<dyn SolveProbe>>);

impl ProbeHandle {
    /// The disabled probe (same as `ProbeHandle::default()`).
    pub fn none() -> Self {
        ProbeHandle(None)
    }

    /// Attach a probe.
    pub fn new(probe: Arc<dyn SolveProbe>) -> Self {
        ProbeHandle(Some(probe))
    }

    /// True when a probe is attached.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The attached probe, when one is present. The coordinator uses this
    /// to fold an already-attached probe (a caller's, or the tracing
    /// [`RingProbe`]) into a [`MultiProbe`] alongside checkpoint and
    /// watchdog members instead of silently replacing it.
    pub fn inner(&self) -> Option<Arc<dyn SolveProbe>> {
        self.0.clone()
    }

    /// Called by solver loops right after they push `r2` (the squared
    /// residual) into the report history. `t0` is the loop's start
    /// instant; the elapsed time is computed only when a probe is
    /// attached.
    #[inline]
    pub fn observe(&self, sweep: usize, r2: f64, t0: Instant) {
        if let Some(p) = &self.0 {
            p.on_sweep(sweep, r2.sqrt(), t0.elapsed().as_nanos() as u64);
        }
    }

    /// True when an attached probe asked for full-state observation
    /// ([`SolveProbe::wants_state`]). Disabled handles return false.
    #[inline]
    pub fn wants_state(&self) -> bool {
        match &self.0 {
            Some(p) => p.wants_state(),
            None => false,
        }
    }

    /// Forward the live iterate to a state-hungry probe. Solvers call
    /// this at the same residual-check points as [`ProbeHandle::observe`],
    /// gated on [`ProbeHandle::wants_state`] so the common path pays one
    /// extra branch and nothing else.
    #[inline]
    pub fn observe_state(&self, sweep: usize, a: &[f32], e: &[f32], r2: f64) {
        if let Some(p) = &self.0 {
            if p.wants_state() {
                p.on_state(sweep, a, e, r2);
            }
        }
    }
}

impl std::fmt::Debug for ProbeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() { "ProbeHandle(on)" } else { "ProbeHandle(off)" })
    }
}

/// One point of a downsampled residual trajectory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrajectoryPoint {
    /// 1-based sweep index at the residual check.
    pub sweep: usize,
    /// `||y - Xa||` at that sweep.
    pub residual_norm: f64,
    /// Nanoseconds since the solve loop started.
    pub elapsed_ns: u64,
}

struct RingInner {
    points: Vec<TrajectoryPoint>,
    stride: usize,
}

/// A [`SolveProbe`] that keeps a bounded residual trajectory by stride
/// doubling: it records every `stride`-th sweep, and when the buffer
/// fills it drops every other retained point and doubles the stride — so
/// an N-point budget covers any sweep count with roughly even spacing and
/// O(1) amortised work per sweep.
pub struct RingProbe {
    cap: usize,
    inner: Mutex<RingInner>,
}

impl RingProbe {
    /// `cap` points are retained at most; cap < 2 is clamped to 2 so the
    /// stride-doubling invariant (always room for sweep 1 and the latest
    /// recorded sweep) holds.
    pub fn new(cap: usize) -> Arc<Self> {
        Arc::new(RingProbe {
            cap: cap.max(2),
            inner: Mutex::new(RingInner { points: Vec::new(), stride: 1 }),
        })
    }

    /// The trajectory recorded so far, in sweep order.
    pub fn snapshot(&self) -> Vec<TrajectoryPoint> {
        self.inner.lock().expect("ring probe lock").points.clone()
    }
}

impl SolveProbe for RingProbe {
    fn on_sweep(&self, sweep: usize, residual_norm: f64, elapsed_ns: u64) {
        let mut g = self.inner.lock().expect("ring probe lock");
        // Solvers may check less often than every sweep (check_every);
        // accept any sweep aligned to the stride, plus the very first
        // observation so short solves are never empty.
        if !g.points.is_empty() && sweep % g.stride != 0 {
            return;
        }
        if g.points.len() == self.cap {
            let s2 = g.stride * 2;
            g.points.retain(|p| p.sweep % s2 == 0 || p.sweep == 1);
            g.stride = s2;
            if sweep % g.stride != 0 {
                return;
            }
        }
        g.points.push(TrajectoryPoint { sweep, residual_norm, elapsed_ns });
    }
}

/// Fans one probe slot out to several observers: a traced, checkpointed,
/// watchdog-guarded solve needs a [`RingProbe`], a
/// [`crate::robust::checkpoint::CheckpointProbe`], and a
/// [`crate::robust::watchdog::Watchdog`] on the same
/// [`crate::solver::SolveOptions::probe`] slot. `wants_state` is the OR
/// of the members', and `on_state` forwards only to members that asked.
pub struct MultiProbe {
    members: Vec<Arc<dyn SolveProbe>>,
}

impl MultiProbe {
    pub fn new(members: Vec<Arc<dyn SolveProbe>>) -> Arc<Self> {
        Arc::new(MultiProbe { members })
    }
}

impl SolveProbe for MultiProbe {
    fn on_sweep(&self, sweep: usize, residual_norm: f64, elapsed_ns: u64) {
        for m in &self.members {
            m.on_sweep(sweep, residual_norm, elapsed_ns);
        }
    }

    fn wants_state(&self) -> bool {
        self.members.iter().any(|m| m.wants_state())
    }

    fn on_state(&self, sweep: usize, a: &[f32], e: &[f32], r2: f64) {
        for m in &self.members {
            if m.wants_state() {
                m.on_state(sweep, a, e, r2);
            }
        }
    }
}

/// Stable span names for per-shard cluster spans. [`SpanRecord::name`]
/// is `&'static str` (so the hot path never allocates); a static table
/// covers the realistic shard counts and everything past it shares one
/// overflow name.
pub fn shard_span_name(i: usize) -> &'static str {
    const NAMES: [&str; 16] = [
        "shard0", "shard1", "shard2", "shard3", "shard4", "shard5", "shard6", "shard7",
        "shard8", "shard9", "shard10", "shard11", "shard12", "shard13", "shard14", "shard15",
    ];
    NAMES.get(i).copied().unwrap_or("shard")
}

static TRACE_IDS: AtomicU64 = AtomicU64::new(1);

/// Mint a process-unique trace id (monotone from 1).
pub fn next_trace_id() -> u64 {
    TRACE_IDS.fetch_add(1, Ordering::Relaxed)
}

/// One named span inside a trace. Timestamps are nanoseconds since the
/// owning [`TraceCtx`]'s epoch; `end_ns == 0` means still open.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// Stage name (`queue_wait`, `route`, `densify`, `stream_io`,
    /// `solve`, `merge`, …).
    pub name: &'static str,
    /// Start, ns since the trace epoch.
    pub start_ns: u64,
    /// End, ns since the trace epoch (0 while open).
    pub end_ns: u64,
    /// Index of the parent span in the trace's span list, if any.
    pub parent: Option<usize>,
}

impl SpanRecord {
    /// Span duration (0 while the span is open).
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// A per-request trace: unique id, a monotonic epoch, and the recorded
/// spans. Shared across threads as `Arc<TraceCtx>` (the request travels
/// submit thread → scheduler → worker).
pub struct TraceCtx {
    id: u64,
    epoch: Instant,
    spans: Mutex<Vec<SpanRecord>>,
}

impl TraceCtx {
    /// A fresh trace with a newly minted id, epoch = now.
    pub fn fresh() -> Arc<Self> {
        Arc::new(TraceCtx {
            id: next_trace_id(),
            epoch: Instant::now(),
            spans: Mutex::new(Vec::new()),
        })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Nanoseconds from the trace epoch to now.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Nanoseconds from the trace epoch to `t` (0 if `t` precedes the
    /// epoch — e.g. a request submitted before tracing was attached).
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Open a span now; returns its index for [`TraceCtx::end`].
    pub fn begin(&self, name: &'static str, parent: Option<usize>) -> usize {
        let start_ns = self.now_ns();
        let mut g = self.spans.lock().expect("trace lock");
        g.push(SpanRecord { name, start_ns, end_ns: 0, parent });
        g.len() - 1
    }

    /// Close the span opened by [`TraceCtx::begin`].
    pub fn end(&self, idx: usize) {
        let end_ns = self.now_ns();
        let mut g = self.spans.lock().expect("trace lock");
        if let Some(s) = g.get_mut(idx) {
            s.end_ns = end_ns;
        }
    }

    /// Record a span whose start/end are already known (e.g. queue wait
    /// reconstructed from the submit timestamp). Returns its index.
    pub fn record_ns(
        &self,
        name: &'static str,
        start_ns: u64,
        end_ns: u64,
        parent: Option<usize>,
    ) -> usize {
        let mut g = self.spans.lock().expect("trace lock");
        g.push(SpanRecord { name, start_ns, end_ns, parent });
        g.len() - 1
    }

    /// Snapshot of the spans recorded so far.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().expect("trace lock").clone()
    }
}

impl std::fmt::Debug for TraceCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCtx").field("id", &self.id).finish_non_exhaustive()
    }
}

/// The observable result of one traced request: span timeline + residual
/// trajectory. Returned under `"telemetry"` in server responses and kept
/// in the coordinator's [`TraceRing`].
#[derive(Clone, Debug)]
pub struct Telemetry {
    pub trace_id: u64,
    pub spans: Vec<SpanRecord>,
    pub trajectory: Vec<TrajectoryPoint>,
}

impl Telemetry {
    /// JSON shape:
    /// `{"trace_id":n,"spans":[{"name","start_ns","end_ns","parent"}],
    ///   "trajectory":[{"sweep","residual_norm","elapsed_ns"}]}`.
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let mut b = ObjBuilder::new()
                    .str("name", s.name)
                    .num("start_ns", s.start_ns as f64)
                    .num("end_ns", s.end_ns as f64);
                if let Some(p) = s.parent {
                    b = b.num("parent", p as f64);
                }
                b.build()
            })
            .collect();
        let traj: Vec<Json> = self
            .trajectory
            .iter()
            .map(|p| {
                ObjBuilder::new()
                    .num("sweep", p.sweep as f64)
                    .num("residual_norm", p.residual_norm)
                    .num("elapsed_ns", p.elapsed_ns as f64)
                    .build()
            })
            .collect();
        ObjBuilder::new()
            .num("trace_id", self.trace_id as f64)
            .val("spans", Json::Arr(spans))
            .val("trajectory", Json::Arr(traj))
            .build()
    }
}

/// Bounded in-memory ring of the most recent [`Telemetry`] records.
pub struct TraceRing {
    cap: usize,
    inner: Mutex<VecDeque<Telemetry>>,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing { cap: cap.max(1), inner: Mutex::new(VecDeque::new()) }
    }

    /// Append a completed trace, evicting the oldest past capacity.
    pub fn push(&self, t: Telemetry) {
        let mut g = self.inner.lock().expect("trace ring lock");
        if g.len() == self.cap {
            g.pop_front();
        }
        g.push_back(t);
    }

    /// The most recent `n` traces, oldest first.
    pub fn recent(&self, n: usize) -> Vec<Telemetry> {
        let g = self.inner.lock().expect("trace ring lock");
        g.iter().rev().take(n).rev().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("trace ring lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_handle_disabled_is_inert() {
        let h = ProbeHandle::default();
        assert!(!h.is_enabled());
        // Must be callable with no probe attached (the solver hot path).
        h.observe(1, 4.0, Instant::now());
        assert_eq!(format!("{h:?}"), "ProbeHandle(off)");
    }

    #[test]
    fn state_observation_is_opt_in() {
        struct StateSink {
            seen: Mutex<Vec<(usize, Vec<f32>, Vec<f32>)>>,
        }
        impl SolveProbe for StateSink {
            fn on_sweep(&self, _s: usize, _r: f64, _e: u64) {}
            fn wants_state(&self) -> bool {
                true
            }
            fn on_state(&self, sweep: usize, a: &[f32], e: &[f32], _r2: f64) {
                self.seen.lock().unwrap().push((sweep, a.to_vec(), e.to_vec()));
            }
        }
        // Default probes (RingProbe) do not want state; disabled handles
        // never do.
        assert!(!ProbeHandle::none().wants_state());
        assert!(!ProbeHandle::new(RingProbe::new(4)).wants_state());
        let sink = Arc::new(StateSink { seen: Mutex::new(Vec::new()) });
        let h = ProbeHandle::new(sink.clone());
        assert!(h.wants_state());
        h.observe_state(3, &[1.0, 2.0], &[0.5], 0.25);
        let seen = sink.seen.lock().unwrap();
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0], (3, vec![1.0, 2.0], vec![0.5]));
    }

    #[test]
    fn multi_probe_fans_out_and_ors_wants_state() {
        struct Counter {
            sweeps: AtomicU64,
            states: AtomicU64,
            hungry: bool,
        }
        impl SolveProbe for Counter {
            fn on_sweep(&self, _s: usize, _r: f64, _e: u64) {
                self.sweeps.fetch_add(1, Ordering::Relaxed);
            }
            fn wants_state(&self) -> bool {
                self.hungry
            }
            fn on_state(&self, _s: usize, _a: &[f32], _e: &[f32], _r2: f64) {
                self.states.fetch_add(1, Ordering::Relaxed);
            }
        }
        let plain = Arc::new(Counter {
            sweeps: AtomicU64::new(0),
            states: AtomicU64::new(0),
            hungry: false,
        });
        let hungry = Arc::new(Counter {
            sweeps: AtomicU64::new(0),
            states: AtomicU64::new(0),
            hungry: true,
        });
        let multi = MultiProbe::new(vec![plain.clone(), hungry.clone()]);
        let h = ProbeHandle::new(multi);
        assert!(h.wants_state(), "one hungry member makes the fan-out hungry");
        h.observe(1, 4.0, Instant::now());
        h.observe_state(1, &[0.0], &[0.0], 0.0);
        assert_eq!(plain.sweeps.load(Ordering::Relaxed), 1);
        assert_eq!(hungry.sweeps.load(Ordering::Relaxed), 1);
        assert_eq!(plain.states.load(Ordering::Relaxed), 0, "non-hungry member skipped");
        assert_eq!(hungry.states.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ring_probe_records_residual_norm_not_squared() {
        let p = RingProbe::new(8);
        let h = ProbeHandle::new(p.clone());
        assert!(h.is_enabled());
        h.observe(1, 9.0, Instant::now());
        let snap = p.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].sweep, 1);
        assert!((snap[0].residual_norm - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ring_probe_downsamples_past_capacity() {
        let p = RingProbe::new(8);
        for sweep in 1..=1000usize {
            p.on_sweep(sweep, 1.0 / sweep as f64, sweep as u64);
        }
        let snap = p.snapshot();
        assert!(snap.len() <= 8, "cap respected, got {}", snap.len());
        assert!(snap.len() >= 2, "long solve keeps multiple points");
        // Sweep order preserved, strictly increasing.
        for w in snap.windows(2) {
            assert!(w[0].sweep < w[1].sweep);
        }
    }

    #[test]
    fn ring_probe_short_solves_keep_every_point() {
        let p = RingProbe::new(32);
        for sweep in 1..=5usize {
            p.on_sweep(sweep, 1.0, 0);
        }
        assert_eq!(p.snapshot().len(), 5);
    }

    #[test]
    fn ring_probe_accepts_sparse_check_cadence() {
        // check_every=50: sweeps arrive as 50, 100, 150, ... — the first
        // observation must be recorded regardless of stride alignment.
        let p = RingProbe::new(8);
        for k in 1..=4usize {
            p.on_sweep(50 * k, 1.0, 0);
        }
        assert!(!p.snapshot().is_empty());
    }

    #[test]
    fn shard_span_names_stable_with_overflow() {
        assert_eq!(shard_span_name(0), "shard0");
        assert_eq!(shard_span_name(15), "shard15");
        assert_eq!(shard_span_name(16), "shard");
        assert_eq!(shard_span_name(usize::MAX), "shard");
    }

    #[test]
    fn trace_ids_unique_and_monotone() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(b > a);
    }

    #[test]
    fn trace_ctx_spans_nest_and_close() {
        let ctx = TraceCtx::fresh();
        let solve = ctx.begin("solve", None);
        let child = ctx.begin("densify", Some(solve));
        ctx.end(child);
        ctx.end(solve);
        let spans = ctx.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "solve");
        assert_eq!(spans[1].parent, Some(0));
        assert!(spans[1].end_ns >= spans[1].start_ns);
        assert!(spans[0].end_ns >= spans[1].end_ns, "parent closes after child");
    }

    #[test]
    fn trace_ctx_record_ns_and_ns_of_saturate() {
        let ctx = TraceCtx::fresh();
        // An instant before the epoch must clamp to 0, not underflow.
        let before = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(1));
        let ctx2 = TraceCtx::fresh();
        assert_eq!(ctx2.ns_of(before), 0);
        let idx = ctx.record_ns("queue_wait", 5, 10, None);
        assert_eq!(ctx.spans()[idx].duration_ns(), 5);
    }

    #[test]
    fn telemetry_json_shape() {
        let t = Telemetry {
            trace_id: 7,
            spans: vec![SpanRecord { name: "solve", start_ns: 1, end_ns: 9, parent: None }],
            trajectory: vec![TrajectoryPoint {
                sweep: 1,
                residual_norm: 0.5,
                elapsed_ns: 100,
            }],
        };
        let j = t.to_json();
        assert_eq!(j.get("trace_id").unwrap().as_f64(), Some(7.0));
        let spans = match j.get("spans").unwrap() {
            Json::Arr(v) => v,
            other => panic!("spans not an array: {other:?}"),
        };
        assert_eq!(spans[0].get("name").unwrap().as_str(), Some("solve"));
        let traj = match j.get("trajectory").unwrap() {
            Json::Arr(v) => v,
            other => panic!("trajectory not an array: {other:?}"),
        };
        assert_eq!(traj[0].get("sweep").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn trace_ring_bounded_and_recent_ordered() {
        let ring = TraceRing::new(3);
        for i in 0..5u64 {
            ring.push(Telemetry { trace_id: i, spans: vec![], trajectory: vec![] });
        }
        assert_eq!(ring.len(), 3);
        let recent = ring.recent(2);
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].trace_id, 3);
        assert_eq!(recent[1].trace_id, 4);
        // Asking for more than retained returns all, oldest first.
        let all = ring.recent(10);
        assert_eq!(all.iter().map(|t| t.trace_id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }
}
