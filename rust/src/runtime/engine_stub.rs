//! Stub PJRT engine, compiled when the `pjrt` cargo feature is off (the
//! `xla` bindings are only present in the rust_pallas image). Same public
//! surface as the real engine module; every entry point reports
//! the runtime as unavailable, which the coordinator and the
//! [`crate::api::PjrtSolver`] already handle by degrading to the native
//! backends.

use anyhow::{bail, Result};

use crate::linalg::Mat;
use crate::solver::{SolveOptions, SolveReport};

use super::manifest::{ArtifactKind, Manifest};

/// Outcome of a PJRT-backed solve, with routing metadata for observability.
#[derive(Clone, Debug)]
pub struct PjrtSolveOutcome {
    pub report: SolveReport,
    /// Artifact the request was routed to.
    pub artifact: String,
    /// Zero-padding overhead: padded elements / true elements - 1.
    pub pad_overhead: f64,
}

/// Stand-in for the compile-once / execute-many PJRT engine.
pub struct Engine {
    manifest: Manifest,
}

const UNAVAILABLE: &str =
    "pjrt runtime not compiled in (build with `--features pjrt` on the rust_pallas image)";

impl Engine {
    /// Always fails: the runtime is not compiled in. The manifest is still
    /// validated so configuration errors surface the same way.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _ = Manifest::load(&artifact_dir)?;
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn warmup(&self) -> Result<usize> {
        bail!("{UNAVAILABLE}")
    }

    pub fn solve(
        &self,
        _x: &Mat,
        _y: &[f32],
        _opts: &SolveOptions,
        _kind: ArtifactKind,
    ) -> Result<PjrtSolveOutcome> {
        bail!("{UNAVAILABLE}")
    }

    pub fn feature_scores(&self, _x: &Mat, _e: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn colnorms_inv_pjrt(&self, _x: &Mat) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_new_reports_unavailable() {
        // Missing artifacts: manifest load error wins.
        assert!(Engine::new("/nonexistent-artifact-dir").is_err());
    }
}
