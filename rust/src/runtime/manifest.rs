//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-repo JSON reader.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// What computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// One sequential Algorithm-1 sweep: (x, cninv, a, e) -> (a', e', r2).
    BakSweep,
    /// One Algorithm-2 sweep: (x, cninv, a, e) -> (a', e', r2).
    BakpSweep,
    /// Algorithm-3 scoring: (x, cninv, e) -> scores.
    Score,
    /// Column-norm precompute: (x) -> cninv.
    Colnorms,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "bak_sweep" => Self::BakSweep,
            "bakp_sweep" => Self::BakpSweep,
            "score" => Self::Score,
            "colnorms" => Self::Colnorms,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::BakSweep => "bak_sweep",
            Self::BakpSweep => "bakp_sweep",
            Self::Score => "score",
            Self::Colnorms => "colnorms",
        }
    }
}

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    /// Static row count (obs) the HLO was lowered for.
    pub obs: usize,
    /// Static column count (vars).
    pub vars: usize,
    /// Block width (blk/thr) baked into the sweep; 0 for score/colnorms.
    pub width: usize,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (dir recorded for file resolution).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").map(Json::items).unwrap_or(&[]) {
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let strings = |k: &str| -> Vec<String> {
                a.get(k)
                    .map(Json::items)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            };
            let dtype = get_str("dtype")?;
            if dtype != "f32" {
                bail!("unsupported artifact dtype {dtype}");
            }
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                kind: ArtifactKind::parse(&get_str("kind")?)?,
                obs: get_usize("obs")?,
                vars: get_usize("vars")?,
                width: get_usize("width")?,
                file: PathBuf::from(get_str("file")?),
                inputs: strings("inputs"),
                outputs: strings("outputs"),
            });
        }
        Ok(Self { dir, artifacts })
    }

    /// Artifacts of a kind, sorted by (obs, vars) ascending — the bucket
    /// search order for routing.
    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.kind == kind).collect();
        v.sort_by_key(|a| (a.obs, a.vars));
        v
    }

    /// Smallest artifact of `kind` that fits an (obs, vars) problem
    /// (inputs are zero-padded up to the bucket shape).
    pub fn route(&self, kind: ArtifactKind, obs: usize, vars: usize) -> Option<&ArtifactSpec> {
        self.of_kind(kind)
            .into_iter()
            .find(|a| a.obs >= obs && a.vars >= vars)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn file_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "bakp_sweep_256x64", "kind": "bakp_sweep", "obs": 256,
         "vars": 64, "width": 32, "dtype": "f32",
         "file": "bakp_sweep_256x64.hlo.txt",
         "inputs": ["x","cninv","a","e"], "outputs": ["a","e","r2"]},
        {"name": "bakp_sweep_1024x128", "kind": "bakp_sweep", "obs": 1024,
         "vars": 128, "width": 64, "dtype": "f32",
         "file": "bakp_sweep_1024x128.hlo.txt",
         "inputs": ["x","cninv","a","e"], "outputs": ["a","e","r2"]},
        {"name": "score_256x64", "kind": "score", "obs": 256, "vars": 64,
         "width": 0, "dtype": "f32", "file": "score_256x64.hlo.txt",
         "inputs": ["x","cninv","e"], "outputs": ["scores"]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].kind, ArtifactKind::BakpSweep);
        assert_eq!(m.artifacts[0].obs, 256);
        assert_eq!(m.artifacts[0].inputs.len(), 4);
    }

    #[test]
    fn route_picks_smallest_fitting_bucket() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let r = m.route(ArtifactKind::BakpSweep, 100, 50).unwrap();
        assert_eq!(r.name, "bakp_sweep_256x64");
        let r = m.route(ArtifactKind::BakpSweep, 300, 50).unwrap();
        assert_eq!(r.name, "bakp_sweep_1024x128");
        assert!(m.route(ArtifactKind::BakpSweep, 5000, 50).is_none());
    }

    #[test]
    fn route_exact_fit() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let r = m.route(ArtifactKind::BakpSweep, 256, 64).unwrap();
        assert_eq!(r.name, "bakp_sweep_256x64");
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("bakp_sweep\",", "weird\",");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn file_path_joins_dir() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/data/arts")).unwrap();
        assert_eq!(
            m.file_path(&m.artifacts[0]),
            PathBuf::from("/data/arts/bakp_sweep_256x64.hlo.txt")
        );
    }

    #[test]
    fn of_kind_sorted() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let v = m.of_kind(ArtifactKind::BakpSweep);
        assert_eq!(v.len(), 2);
        assert!(v[0].obs < v[1].obs);
    }
}
