//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the request path.
//!
//! Flow (see /opt/xla-example/load_hlo/): HLO text ->
//! [`xla::HloModuleProto::from_text_file`] -> [`xla::XlaComputation`] ->
//! `client.compile` -> cached [`xla::PjRtLoadedExecutable`] -> `execute_b`.
//!
//! Text is the interchange format because jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1's proto path rejects; the text
//! parser reassigns ids.

//! The real engine needs the `xla` bindings baked into the rust_pallas
//! image, gated behind the `pjrt` cargo feature. Without it a stub
//! `Engine` with the same surface compiles whose constructor always
//! errors, so the coordinator degrades to the native backends.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use engine::{Engine, PjrtSolveOutcome};
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};
