//! The PJRT execution engine: compile-once, execute-many.
//!
//! One [`Engine`] wraps one `PjRtClient` (CPU here; the same artifacts
//! compile for TPU given a TPU PJRT plugin) plus a cache of compiled
//! executables keyed by artifact name. The solve loop keeps the big `x`
//! operand **device-resident** across sweeps (`execute_b` on
//! `PjRtBuffer`s) — only the small `a`/`e` vectors round-trip per sweep,
//! mirroring the paper's GPU story where the matrix stays on the
//! accelerator.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::linalg::{blas1, Mat};
use crate::solver::{SolveOptions, SolveReport, StopReason};

use super::manifest::{ArtifactKind, ArtifactSpec, Manifest};

/// Outcome of a PJRT-backed solve, with routing metadata for observability.
#[derive(Clone, Debug)]
pub struct PjrtSolveOutcome {
    pub report: SolveReport,
    /// Artifact the request was routed to.
    pub artifact: String,
    /// Zero-padding overhead: padded elements / true elements - 1.
    pub pad_overhead: f64,
}

struct Loaded {
    /// Artifact metadata (kept for debugging/observability dumps).
    #[allow(dead_code)]
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Compile-once / execute-many PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazily compiled executables, keyed by artifact name.
    cache: Mutex<HashMap<String, std::sync::Arc<Loaded>>>,
}

// xla handles are internally refcounted; the engine serialises compilation
// through the cache mutex and execution is externally synchronised by the
// coordinator's worker ownership model.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for an artifact name.
    fn load(&self, name: &str) -> Result<std::sync::Arc<Loaded>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(l) = cache.get(name) {
            return Ok(l.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.manifest.file_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let loaded = std::sync::Arc::new(Loaded { spec, exe });
        cache.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Eagerly compile every artifact (startup warm-up).
    pub fn warmup(&self) -> Result<usize> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("host->device transfer: {e:?}"))
    }

    /// Solve x a ≈ y by repeatedly executing a sweep artifact.
    ///
    /// Routing: the smallest `kind` bucket with obs >= x.rows() and
    /// vars >= x.cols(); inputs are zero-padded to the bucket shape (zero
    /// rows/columns are inert: padded columns have cninv = 0 and padded
    /// rows contribute nothing to any inner product). Rust owns the
    /// convergence loop, so tolerance early-break works exactly as in the
    /// native solvers.
    pub fn solve(
        &self,
        x: &Mat,
        y: &[f32],
        opts: &SolveOptions,
        kind: ArtifactKind,
    ) -> Result<PjrtSolveOutcome> {
        let (obs, vars) = x.shape();
        if y.len() != obs {
            bail!("y length {} != obs {obs}", y.len());
        }
        if !matches!(kind, ArtifactKind::BakSweep | ArtifactKind::BakpSweep) {
            bail!("solve() needs a sweep artifact, got {}", kind.as_str());
        }
        let spec = self
            .manifest
            .route(kind, obs, vars)
            .ok_or_else(|| {
                anyhow!("no {} artifact fits {}x{} (rebuild with a larger menu)", kind.as_str(), obs, vars)
            })?
            .clone();
        let loaded = self.load(&spec.name)?;

        // Zero-pad to the bucket shape. jax lowered x as (obs, vars) with
        // XLA's default row-major layout, while Mat is col-major — build
        // the padded row-major image directly.
        let (pobs, pvars) = (spec.obs, spec.vars);
        let mut x_rm = vec![0.0f32; pobs * pvars];
        for j in 0..vars {
            let col = x.col(j);
            for i in 0..obs {
                x_rm[i * pvars + j] = col[i];
            }
        }
        let mut yp = vec![0.0f32; pobs];
        yp[..obs].copy_from_slice(y);
        let cninv: Vec<f32> = {
            let mut v = crate::solver::colnorms_inv(x);
            v.resize(pvars, 0.0); // padded columns: cninv = 0 -> inert
            v
        };
        let pad_overhead = (pobs * pvars) as f64 / (obs * vars) as f64 - 1.0;

        // x and cninv stay device-resident across all sweeps.
        let x_buf = self.upload(&x_rm, &[pobs, pvars])?;
        let cn_buf = self.upload(&cninv, &[pvars])?;

        let y_norm_sq = blas1::sum_sq_f64(y);
        let tol_sq = opts.tol * opts.tol * y_norm_sq;
        let mut a = vec![0.0f32; pvars];
        let mut e = yp.clone();
        let mut history = Vec::new();
        let mut stop = StopReason::MaxSweeps;
        let mut sweeps = 0;
        let mut prev_r2 = f64::INFINITY;

        for sweep in 0..opts.max_sweeps {
            let a_buf = self.upload(&a, &[pvars])?;
            let e_buf = self.upload(&e, &[pobs])?;
            let outs = loaded
                .exe
                .execute_b(&[&x_buf, &cn_buf, &a_buf, &e_buf])
                .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?;
            let tuple = outs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("device->host: {e:?}"))?;
            let (la, le, lr2) = tuple
                .to_tuple3()
                .map_err(|e| anyhow!("expected 3-tuple output: {e:?}"))?;
            a = la.to_vec::<f32>().map_err(|e| anyhow!("a readback: {e:?}"))?;
            e = le.to_vec::<f32>().map_err(|e| anyhow!("e readback: {e:?}"))?;
            let r2 = lr2.to_vec::<f32>().map_err(|e| anyhow!("r2 readback: {e:?}"))?[0] as f64;
            sweeps = sweep + 1;
            history.push(r2);
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }

        a.truncate(vars);
        e.truncate(obs);
        Ok(PjrtSolveOutcome {
            report: SolveReport { a, e, history, y_norm_sq, sweeps, stop },
            artifact: spec.name.clone(),
            pad_overhead,
        })
    }

    /// Run a score artifact: feature scores for (x, e).
    pub fn feature_scores(&self, x: &Mat, e: &[f32]) -> Result<Vec<f32>> {
        let (obs, vars) = x.shape();
        let spec = self
            .manifest
            .route(ArtifactKind::Score, obs, vars)
            .ok_or_else(|| anyhow!("no score artifact fits {obs}x{vars}"))?
            .clone();
        let loaded = self.load(&spec.name)?;
        let (pobs, pvars) = (spec.obs, spec.vars);
        let mut x_rm = vec![0.0f32; pobs * pvars];
        for j in 0..vars {
            let col = x.col(j);
            for i in 0..obs {
                x_rm[i * pvars + j] = col[i];
            }
        }
        let mut cninv = crate::solver::colnorms_inv(x);
        cninv.resize(pvars, 0.0);
        let mut ep = vec![0.0f32; pobs];
        ep[..obs].copy_from_slice(e);

        let x_buf = self.upload(&x_rm, &[pobs, pvars])?;
        let cn_buf = self.upload(&cninv, &[pvars])?;
        let e_buf = self.upload(&ep, &[pobs])?;
        let outs = loaded
            .exe
            .execute_b(&[&x_buf, &cn_buf, &e_buf])
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?;
        let scores = tuple
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("scores readback: {e:?}"))?;
        Ok(scores[..vars].to_vec())
    }

    /// Execute a colnorms artifact (used by tests to cross-check the
    /// native precompute).
    pub fn colnorms_inv_pjrt(&self, x: &Mat) -> Result<Vec<f32>> {
        let (obs, vars) = x.shape();
        let spec = self
            .manifest
            .route(ArtifactKind::Colnorms, obs, vars)
            .ok_or_else(|| anyhow!("no colnorms artifact fits {obs}x{vars}"))?
            .clone();
        let loaded = self.load(&spec.name)?;
        let (pobs, pvars) = (spec.obs, spec.vars);
        let mut x_rm = vec![0.0f32; pobs * pvars];
        for j in 0..vars {
            let col = x.col(j);
            for i in 0..obs {
                x_rm[i * pvars + j] = col[i];
            }
        }
        let x_buf = self.upload(&x_rm, &[pobs, pvars])?;
        let outs = loaded
            .exe
            .execute_b(&[&x_buf])
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?;
        let v = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        Ok(v[..vars].to_vec())
    }

    /// Load + compile an arbitrary HLO file and return its executable
    /// (escape hatch used by the smoke example).
    pub fn compile_hlo_file(&self, path: impl AsRef<std::path::Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }
}
