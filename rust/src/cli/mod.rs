//! Hand-rolled CLI (the offline registry has no clap): a small typed
//! argument parser plus the `solvebak` subcommands.

pub mod args;
pub mod commands;

pub use args::{ArgError, Args};
pub use commands::run;
