//! The `solvebak` subcommands.
//!
//! ```text
//! solvebak solve    --obs 1e5 --vars 100 [--backend bak|bakp|qr|pjrt|auto]
//!                   [--sparse --density 0.01]
//!                   [--x-file X.sbck --mem-budget 8e6]
//! solvebak convert  --obs 1e6 --vars 256 --out X.sbck [--chunk 64]
//! solvebak features --obs 1e4 --vars 200 --max-feat 10
//! solvebak serve    --requests 64 --workers 4 [--artifacts DIR]
//! solvebak serve-worker --port 7450 [--worker-id w1 --max-inflight 4]
//! solvebak stats    --addr 127.0.0.1:7447 [--interval 1.0 --count 0]
//! solvebak info     [--artifacts DIR]
//! ```
//!
//! Everything prints human-readable lines plus a final JSON record for
//! machine consumption.

use std::sync::Arc;

use crate::api::{registry, SolverKind};
use crate::bench::workload::{SparseWorkload, Workload, WorkloadSpec};
use crate::coordinator::{Coordinator, CoordinatorConfig, SolveRequest};
use crate::solver::{self, BakfOptions, SolveOptions};
use crate::util::json::ObjBuilder;
use crate::util::stats::mape;
use crate::util::timer::{fmt_seconds, time_once};

use super::args::{ArgError, Args};

/// Help text; the `--backend` list is derived from the solver registry so
/// it can never drift from what actually dispatches.
fn usage() -> String {
    let backends: Vec<&'static str> = registry().iter().map(|s| s.name()).collect();
    format!(
        "solvebak — SolveBak/SolveBakP/SolveBakF solver service (Bakas 2021 reproduction)

USAGE:
  solvebak <COMMAND> [OPTIONS]

COMMANDS:
  solve      solve one synthetic system and report accuracy/time
  convert    generate a planted system straight into a chunked .sbck file
             (plus a .y right-hand-side sidecar) — out-of-core, one chunk
             resident at a time; --sparse converts a COO workload instead
  features   run SolveBakF feature selection on a planted workload
  serve      run the coordinator service against synthetic request load
  serve-tcp  expose the coordinator on a TCP port (newline-JSON protocol)
  serve-worker
             run a cluster shard worker: answers the v1.2 join/heartbeat/
             shard_solve commands for a serve-tcp --cluster coordinator
  stats      live dashboard: poll a serve-tcp instance's metrics and print
             one line per interval (req/s, latency quantiles, queue depth)
  info       environment + artifact inventory
  help       this text

COMMON OPTIONS:
  --obs N --vars N      problem shape (scientific notation ok: 1e6)
  --seed N              workload seed            [42]
  --backend NAME        solver backend           [auto]
                        one of: {}|auto
  --sparse              sparse workload (CSC storage, O(nnz) solves)
  --density X           sparse nonzero fraction  [0.01] (implies --sparse)
  --thr N               BAKP block width         [50]
  --threads N           solver threads (bak_par/kaczmarz_par blocks, BAKP
                        in-block threading; auto-routing prefers the
                        parallel variants when > 1)
                        [PALLAS_THREADS, else 1]
  --x-file PATH         solve a file-backed chunked (.sbck) matrix with the
                        out-of-core streaming engine; the right-hand side
                        comes from --y-file, default PATH.y
  --y-file PATH         f32-LE right-hand-side sidecar for --x-file
  --mem-budget BYTES    streaming buffer-pool byte budget [8 MiB]
  --chunk N             convert: columns per chunk       [~1 MiB per chunk]
  --out PATH            convert: output .sbck path (required)
  --sweeps N --tol X    convergence control      [200/1e-6]
  --artifacts DIR       PJRT artifact directory  [artifacts]
  --max-feat N          features to select       [10]
  --workers N           service worker threads   [PALLAS_THREADS, else
                        available parallelism]
  --requests N          synthetic request count  [32]
  --addr HOST:PORT      stats: serve-tcp address [127.0.0.1:7447]
  --interval SECS       stats: polling period    [1.0]
  --count N             stats: lines to print, 0 = until interrupted [0]

ROBUSTNESS (see PROTOCOL.md):
  --deadline-ms N       solve: wall-clock budget; an expired solve reports
                        deadline_exceeded instead of running to completion
  --max-inflight N      serve-tcp: admission-gate slots, 0 = unlimited
  --max-queue-wait-ms N serve-tcp: wait this long for a slot before shedding
  --degraded-sweeps N   serve-tcp: answer shed requests with a reduced-sweep
                        BAK solve instead of an overloaded error
  --faults SPEC         serve-tcp: arm fault injection, e.g.
                        worker_panic_every=7,queue_stall_ms=20
                        (the PALLAS_FAULTS env var arms the same knobs)
  --retries N           stats: client retry budget on overload/transport [3]

DURABILITY (see PROTOCOL.md §durability):
  --journal-dir DIR     serve-tcp: persist per-job checkpoints so a solve
                        re-submitted under the same job_id resumes instead
                        of starting over [off]
  --checkpoint-every N  serve-tcp: sweeps between checkpoint writes [8]

CLUSTER (see PROTOCOL.md §cluster):
  --cluster             serve-tcp: shard kaczmarz_par/bak_par solves across
                        remote workers (requires --workers-addrs)
  --workers-addrs LIST  serve-tcp: comma-separated worker HOST:PORT list
  --shards N            serve-tcp: shards per clustered solve; 0 = use the
                        request's --threads value [0]
  --heartbeat-ms N      serve-tcp: worker liveness probe period, 0 = off
                        [500]
  --worker-id NAME      serve-worker: stable worker identity [worker-PORT]
  --port N / --max-inflight N
                        serve-worker: listen port [7450] and shard_solve
                        admission slots, 0 = unlimited [0]
",
        backends.join("|")
    )
}

/// Entry point used by main(). Returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match run_inner(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `solvebak help` for usage");
            2
        }
    }
}

fn run_inner(argv: Vec<String>) -> Result<(), ArgError> {
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let args = Args::parse(&argv[argv.len().min(1)..])?;
    match cmd.as_str() {
        "solve" => cmd_solve(&args),
        "convert" => cmd_convert(&args),
        "features" => cmd_features(&args),
        "serve" => cmd_serve(&args),
        "serve-tcp" => cmd_serve_tcp(&args),
        "serve-worker" => cmd_serve_worker(&args),
        "stats" => cmd_stats(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(ArgError(format!("unknown command '{other}'"))),
    }
}

fn backend_of(args: &Args) -> Result<SolverKind, ArgError> {
    args.get("backend")
        .unwrap_or("auto")
        .parse::<SolverKind>()
        .map_err(|e| ArgError(e.to_string()))
}

/// Default for `--threads` when the flag is absent: `PALLAS_THREADS` when
/// set, else 1 (the solver-side serial default — the service worker pool
/// separately defaults to the machine's parallelism via
/// [`crate::parallel::default_threads`]).
fn threads_default() -> usize {
    crate::parallel::env_threads().unwrap_or(1)
}

fn opts_of(args: &Args) -> Result<SolveOptions, ArgError> {
    Ok(SolveOptions::builder()
        .max_sweeps(args.get_usize("sweeps", 200)?)
        .tol(args.get_f64("tol", 1e-6)?)
        .thr(args.get_usize("thr", 50)?)
        .threads(args.get_usize("threads", threads_default())?)
        .seed(args.get_u64("seed", 0x5eed)?)
        .build())
}

fn cmd_solve(args: &Args) -> Result<(), ArgError> {
    let mut obs = args.get_usize("obs", 10_000)?;
    let mut vars = args.get_usize("vars", 100)?;
    let seed = args.get_u64("seed", 42)?;
    let sparse = args.flag("sparse") || args.get("density").is_some();
    let density = args.get_f64("density", 0.01)?;
    let backend = backend_of(args)?;
    let opts = opts_of(args)?;

    // --x-file solves an on-disk chunked matrix (the payload never loads
    // into RAM); otherwise the dense path plants via Workload::consistent
    // and sparse via the CSC generator — both exactly consistent, so mape
    // is comparable.
    let spec = WorkloadSpec::new(obs, vars, seed);
    let (matrix, y, a_true, nnz) = if let Some(xf) = args.get("x-file") {
        let mut s = crate::stream::StreamedMatrix::open(xf)
            .map_err(|e| ArgError(format!("--x-file {xf}: {e}")))?;
        let budget = args.get_usize("mem-budget", 0)?;
        if budget > 0 {
            s = s.with_budget(budget);
        }
        let y_path = args
            .get("y-file")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| sidecar_y_path(s.path()));
        let y = crate::stream::read_vec_f32(&y_path)
            .map_err(|e| ArgError(format!("y file {}: {e}", y_path.display())))?;
        (obs, vars) = s.shape();
        let nnz = obs * vars;
        (
            crate::coordinator::request::SharedMatrix::Streamed(Arc::new(s)),
            y,
            None,
            nnz,
        )
    } else if sparse {
        let w = SparseWorkload::uniform(spec, density);
        let nnz = w.x.nnz();
        (
            crate::coordinator::request::SharedMatrix::SparseCsc(Arc::new(w.x)),
            w.y,
            Some(w.a_true),
            nnz,
        )
    } else {
        let w = Workload::consistent(spec);
        let nnz = obs * vars;
        (
            crate::coordinator::request::SharedMatrix::Dense(Arc::new(w.x)),
            w.y,
            w.a_true,
            nnz,
        )
    };
    let streamed = matrix.is_streamed();

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        artifact_dir: Some(args.get("artifacts").unwrap_or("artifacts").into()),
        ..CoordinatorConfig::default()
    });
    let mut builder = SolveRequest::builder(1, matrix, y).backend(backend).opts(opts);
    if let Some(ms) = args.get("deadline-ms") {
        builder = builder.deadline_ms(
            ms.parse::<u64>()
                .map_err(|_| ArgError(format!("--deadline-ms: bad integer '{ms}'")))?,
        );
    }
    let req = builder.build();
    // submit_robust (not solve_blocking) so --deadline-ms arms the
    // cancellation token exactly like a TCP request would.
    let (res, secs) = time_once(|| match coord.submit_robust(req) {
        Ok(rx) => rx
            .recv()
            .map_err(|_| crate::api::SolverError::Service("reply channel dropped".into())),
        Err(e) => Err(e),
    });
    let out = res.map_err(|e| ArgError(e.to_string()))?;
    let report = out.report.map_err(|e| ArgError(e.to_string()))?;
    let acc = a_true.as_ref().map(|t| mape(&report.a, t)).unwrap_or(f64::NAN);

    let kind = if streamed {
        "streamed "
    } else if sparse {
        "sparse "
    } else {
        ""
    };
    let peak_rss = crate::util::alloc::peak_rss_bytes();
    println!(
        "solved {kind}{obs}x{vars} (nnz={nnz}) via {}: {} | sweeps={} stop={:?} rel_resid={:.3e} mape={:.3e} peak_rss={}",
        out.backend, fmt_seconds(secs), report.sweeps, report.stop,
        report.rel_residual(), acc, fmt_peak_rss(peak_rss),
    );
    let mut b = ObjBuilder::new()
        .str("cmd", "solve")
        .num("obs", obs as f64)
        .num("vars", vars as f64)
        .bool("sparse", sparse)
        .bool("streamed", streamed)
        .num("nnz", nnz as f64)
        .str("backend", out.backend.to_string())
        .num("seconds", secs)
        .num("sweeps", report.sweeps as f64)
        .num("rel_residual", report.rel_residual())
        .num("mape", acc);
    if let Some(rss) = peak_rss {
        b = b.num("peak_rss_bytes", rss as f64);
    }
    println!("{}", b.build().to_string());
    coord.shutdown();
    Ok(())
}

/// Human-readable peak-RSS suffix: "12.3MiB", or "n/a" where the metric
/// is unavailable (see [`crate::util::alloc::peak_rss_bytes`]).
fn fmt_peak_rss(rss: Option<u64>) -> String {
    rss.map_or_else(
        || "n/a".to_string(),
        |b| format!("{:.1}MiB", crate::util::alloc::mib(b)),
    )
}

/// The `<x>.y` sidecar path next to a chunked matrix file.
fn sidecar_y_path(x: &std::path::Path) -> std::path::PathBuf {
    let mut s = x.as_os_str().to_os_string();
    s.push(".y");
    std::path::PathBuf::from(s)
}

/// `solvebak convert`: generate a planted system straight into a chunked
/// `.sbck` file plus its `.y` sidecar. The dense path streams
/// chunk-by-chunk through [`crate::stream::write_chunked_with`] — peak
/// memory is one chunk plus the y vector, never the full matrix — so CI
/// can produce inputs far larger than the solve-side `--mem-budget`.
fn cmd_convert(args: &Args) -> Result<(), ArgError> {
    let obs = args.get_usize("obs", 10_000)?;
    let vars = args.get_usize("vars", 100)?;
    let seed = args.get_u64("seed", 42)?;
    let sparse = args.flag("sparse") || args.get("density").is_some();
    let density = args.get_f64("density", 0.01)?;
    let out = args
        .get("out")
        .ok_or_else(|| ArgError("convert: --out PATH is required".into()))?;
    if obs == 0 || vars == 0 {
        return Err(ArgError(format!("convert: empty shape {obs}x{vars}")));
    }
    let path = std::path::PathBuf::from(out);
    let chunk = match args.get_usize("chunk", 0)? {
        0 => crate::stream::default_chunk_cols(obs, vars),
        c => c,
    };
    let io_err = |e: std::io::Error| ArgError(format!("{}: {e}", path.display()));

    let t0 = std::time::Instant::now();
    let y = if sparse {
        let w = SparseWorkload::uniform(WorkloadSpec::new(obs, vars, seed), density);
        crate::stream::write_chunked_csc(&w.x, chunk, &path).map_err(io_err)?;
        w.y
    } else {
        // Planted coefficients from a split stream, then X generated in
        // column-major chunk order while y = X·a accumulates per column.
        let mut rng = crate::util::rng::Rng::seed(seed);
        let mut arng = rng.split();
        let a_true: Vec<f32> = (0..vars).map(|_| arng.normal_f32()).collect();
        let mut y = vec![0.0f32; obs];
        crate::stream::write_chunked_with(&path, obs, vars, chunk, |j0, width, buf| {
            rng.fill_normal(buf);
            for l in 0..width {
                let col = &buf[l * obs..(l + 1) * obs];
                crate::linalg::blas1::axpy(a_true[j0 + l], col, &mut y);
            }
        })
        .map_err(io_err)?;
        y
    };
    let y_path = sidecar_y_path(&path);
    crate::stream::write_vec_f32(&y_path, &y)
        .map_err(|e| ArgError(format!("{}: {e}", y_path.display())))?;
    let secs = t0.elapsed().as_secs_f64();

    let meta = crate::stream::StreamedMatrix::open(&path).map_err(io_err)?;
    let peak_rss = crate::util::alloc::peak_rss_bytes();
    println!(
        "wrote {} ({obs}x{vars}, chunk_cols={}, {:.1} MiB) + {} in {} | peak_rss={}",
        path.display(),
        meta.chunk_cols(),
        crate::util::alloc::mib(meta.nbytes() as u64),
        y_path.display(),
        fmt_seconds(secs),
        fmt_peak_rss(peak_rss),
    );
    let mut b = ObjBuilder::new()
        .str("cmd", "convert")
        .num("obs", obs as f64)
        .num("vars", vars as f64)
        .bool("sparse", sparse)
        .num("chunk_cols", meta.chunk_cols() as f64)
        .num("bytes", meta.nbytes() as f64)
        .str("out", path.display().to_string())
        .num("seconds", secs);
    if let Some(rss) = peak_rss {
        b = b.num("peak_rss_bytes", rss as f64);
    }
    println!("{}", b.build().to_string());
    Ok(())
}

fn cmd_features(args: &Args) -> Result<(), ArgError> {
    let obs = args.get_usize("obs", 10_000)?;
    let vars = args.get_usize("vars", 200)?;
    let k = args.get_usize("max-feat", 10)?;
    let seed = args.get_u64("seed", 42)?;
    let noise = args.get_f64("noise", 0.01)? as f32;
    let (w, support) = Workload::sparse_support(WorkloadSpec::new(obs, vars, seed), k, noise);

    let (rep, secs) = time_once(|| {
        solver::select_features_bakf(&w.x, &w.y, &BakfOptions { max_feat: k, ..Default::default() })
    });
    let mut got = rep.selected.clone();
    got.sort_unstable();
    let hits = got.iter().filter(|j| support.contains(j)).count();
    println!(
        "selected {:?} in {} | planted {:?} | recovered {hits}/{}",
        rep.selected, fmt_seconds(secs), support, support.len(),
    );
    println!(
        "{}",
        ObjBuilder::new()
            .str("cmd", "features")
            .num("obs", obs as f64)
            .num("vars", vars as f64)
            .num("max_feat", k as f64)
            .num("seconds", secs)
            .num("recovered", hits as f64)
            .num("planted", support.len() as f64)
            .build()
            .to_string()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), ArgError> {
    let n = args.get_usize("requests", 32)?;
    let workers = args.get_usize("workers", crate::parallel::default_threads())?;
    let obs = args.get_usize("obs", 2_000)?;
    let vars = args.get_usize("vars", 64)?;
    let seed = args.get_u64("seed", 42)?;
    let backend = backend_of(args)?;

    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        artifact_dir: Some(args.get("artifacts").unwrap_or("artifacts").into()),
        ..CoordinatorConfig::default()
    });
    // A small pool of shared matrices so the batcher has coalescing
    // opportunities — the serving scenario.
    let mut rng = crate::util::rng::Rng::seed(seed);
    let pool: Vec<Arc<crate::linalg::Mat>> = (0..4)
        .map(|_| Arc::new(crate::linalg::Mat::randn(&mut rng, obs, vars)))
        .collect();

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let x = pool[i % pool.len()].clone();
            let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
            let y = x.matvec(&a);
            let req = SolveRequest::builder(i as u64, x, y).backend(backend).build();
            coord.submit(req).map_err(|e| ArgError(e.to_string()))
        })
        .collect::<Result<_, _>>()?;
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().map(|o| o.report.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let total = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{n} requests in {} ({:.1} req/s) with {workers} workers",
        fmt_seconds(total), n as f64 / total,
    );
    println!("{}", coord.metrics().to_json().to_string());
    coord.shutdown();
    Ok(())
}

/// Parse the `--cluster`/`--workers-addrs`/`--shards`/`--heartbeat-ms`
/// knobs into a [`crate::cluster::ClusterConfig`]. `None` when neither
/// cluster flag is present; an error when `--cluster` is armed without
/// worker addresses.
fn cluster_config_of(args: &Args) -> Result<Option<crate::cluster::ClusterConfig>, ArgError> {
    if !args.flag("cluster") && args.get("workers-addrs").is_none() {
        return Ok(None);
    }
    let addrs = args.get("workers-addrs").ok_or_else(|| {
        ArgError("--cluster needs --workers-addrs HOST:PORT[,HOST:PORT...]".into())
    })?;
    let workers: Vec<String> = addrs
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if workers.is_empty() {
        return Err(ArgError("--workers-addrs: no addresses given".into()));
    }
    let shards = match args.get_usize("shards", 0)? {
        0 => None,
        n => Some(n),
    };
    Ok(Some(crate::cluster::ClusterConfig {
        workers,
        shards,
        heartbeat_ms: args.get_u64("heartbeat-ms", 500)?,
    }))
}

fn cmd_serve_tcp(args: &Args) -> Result<(), ArgError> {
    let workers = args.get_usize("workers", crate::parallel::default_threads())?;
    let port = args.get_usize("port", 7447)? as u16;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let max_queue_wait_ms = args.get_u64("max-queue-wait-ms", 0)?;
    let degraded_sweeps = match args.get_usize("degraded-sweeps", 0)? {
        0 => None,
        n => Some(n),
    };
    let journal_dir = args.get("journal-dir").map(std::path::PathBuf::from);
    let checkpoint_every = args.get_usize("checkpoint-every", 8)?;
    let cluster = cluster_config_of(args)?;
    if let Some(spec) = args.get("faults") {
        let plan = crate::robust::faults::FaultPlan::parse(spec).map_err(ArgError)?;
        crate::robust::faults::install(&plan);
        println!("fault injection armed: {plan}");
    }
    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers,
        artifact_dir: Some(args.get("artifacts").unwrap_or("artifacts").into()),
        max_inflight,
        max_queue_wait_ms,
        degraded_sweeps,
        journal_dir: journal_dir.clone(),
        checkpoint_every,
        cluster: cluster.clone(),
        ..CoordinatorConfig::default()
    }));
    let server = crate::coordinator::server::Server::bind(coord.clone(), port)
        .map_err(|e| ArgError(format!("bind: {e}")))?;
    println!("listening on {} ({} workers)", server.addr(), workers);
    if let Some(dir) = &journal_dir {
        println!(
            "durable jobs: journal at {} (checkpoint every {checkpoint_every} sweeps)",
            dir.display()
        );
    }
    if max_inflight > 0 {
        println!(
            "admission gate: {max_inflight} in flight, {max_queue_wait_ms}ms queue wait, \
             degraded sweeps: {}",
            degraded_sweeps.map_or("off".to_string(), |n| n.to_string()),
        );
    }
    if let Some(c) = &cluster {
        println!(
            "cluster: {} worker(s) at {} | shards {} | heartbeat {}ms",
            c.workers.len(),
            c.workers.join(","),
            c.shards.map_or("per-request --threads".to_string(), |n| n.to_string()),
            c.heartbeat_ms,
        );
    }
    println!("protocol: v1 newline-delimited JSON (PROTOCOL.md); send {{\"cmd\":\"shutdown\"}} to stop.");
    // Block until a client sends the shutdown command (the accept loop
    // exits when the stop flag flips).
    while !server.stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    println!("shutdown requested; final metrics: {}", coord.metrics().to_json().to_string());
    server.stop();
    Ok(())
}

/// `solvebak serve-worker`: run one cluster shard worker. It holds no
/// problem data until a coordinator dispatches shards, so it can start
/// before, after, or instead of any particular coordinator — membership
/// is the coordinator's job (PROTOCOL.md §cluster). The process runs
/// until killed; workers are designed to die abruptly (the coordinator
/// reshards around the loss), so there is no graceful-shutdown command.
fn cmd_serve_worker(args: &Args) -> Result<(), ArgError> {
    let port = args.get_usize("port", 7450)? as u16;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let worker_id = args
        .get("worker-id")
        .map(str::to_string)
        .unwrap_or_else(|| format!("worker-{port}"));
    let mut core = crate::cluster::WorkerCore::new(worker_id.clone());
    if max_inflight > 0 {
        core = core.with_max_inflight(max_inflight);
    }
    let server = crate::cluster::WorkerServer::bind(Arc::new(core), port)
        .map_err(|e| ArgError(format!("bind: {e}")))?;
    println!(
        "worker '{worker_id}' listening on {} (v1.2 commands: {}; PROTOCOL.md §cluster)",
        server.addr(),
        crate::cluster::worker::WORKER_COMMANDS.join("/"),
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// One polled metrics snapshot — the fields the `stats` dashboard renders.
#[derive(Clone, Copy, Debug, Default)]
struct StatsSnap {
    requests_completed: f64,
    requests_failed: f64,
    p50_s: f64,
    p99_s: f64,
    queue_depth: f64,
    workers: f64,
    workers_busy: f64,
    stream_stalls: f64,
}

impl StatsSnap {
    /// Extract from a `{"cmd":"metrics"}` response.
    fn from_json(j: &crate::util::json::Json) -> Self {
        let f = |k: &str| j.get(k).and_then(crate::util::json::Json::as_f64).unwrap_or(0.0);
        Self {
            requests_completed: f("requests_completed"),
            requests_failed: f("requests_failed"),
            p50_s: f("solve_latency_p50_s"),
            p99_s: f("solve_latency_p99_s"),
            queue_depth: f("job_queue_depth"),
            workers: f("workers"),
            workers_busy: f("workers_busy"),
            stream_stalls: f("stream_buffer_stalls"),
        }
    }
}

/// Render one dashboard line. Rates are deltas against the previous poll
/// over `dt` seconds; the first line (no previous) shows absolute totals.
/// Pure — unit-tested without a TCP server.
fn stats_line(cur: &StatsSnap, prev: Option<&StatsSnap>, dt: f64) -> String {
    let (rate, fail_rate) = match prev {
        Some(p) if dt > 0.0 => (
            (cur.requests_completed - p.requests_completed).max(0.0) / dt,
            (cur.requests_failed - p.requests_failed).max(0.0) / dt,
        ),
        _ => (cur.requests_completed, cur.requests_failed),
    };
    let unit = if prev.is_some() { "req/s" } else { "req total" };
    format!(
        "{rate:8.1} {unit} | fail {fail_rate:6.1} | p50 {:7.2}ms p99 {:7.2}ms | queue {:4.0} | busy {:.0}/{:.0} | stalls {:5.0}",
        cur.p50_s * 1e3,
        cur.p99_s * 1e3,
        cur.queue_depth,
        cur.workers_busy,
        cur.workers,
        cur.stream_stalls,
    )
}

/// `solvebak stats`: poll a running serve-tcp instance's `metrics` command
/// and print a one-line dashboard per interval. Polls go through
/// [`crate::client::Client`], so a restarting or briefly overloaded server
/// costs retries (`--retries`), not a dead dashboard.
fn cmd_stats(args: &Args) -> Result<(), ArgError> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:7447");
    let interval = args.get_f64("interval", 1.0)?.max(0.05);
    let count = args.get_usize("count", 0)?;
    let retries = args.get_usize("retries", 3)? as u32;

    let policy = crate::client::RetryPolicy {
        max_retries: retries,
        ..crate::client::RetryPolicy::default()
    };
    let mut client = crate::client::Client::with_policy(addr, policy);
    let req = crate::util::json::Json::parse(r#"{"cmd": "metrics"}"#)
        .expect("static metrics request parses");
    println!("polling {addr} every {interval}s ({} lines, {retries} retries)",
             if count == 0 { "unbounded".to_string() } else { count.to_string() });

    let mut prev: Option<StatsSnap> = None;
    let mut printed = 0usize;
    loop {
        let j = client
            .request(&req)
            .map_err(|e| ArgError(format!("{addr}: {e}")))?;
        let cur = StatsSnap::from_json(&j);
        println!("{}", stats_line(&cur, prev.as_ref(), interval));
        prev = Some(cur);
        printed += 1;
        if count != 0 && printed >= count {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

fn cmd_info(args: &Args) -> Result<(), ArgError> {
    println!("solvebak {} — three-layer Rust+JAX+Pallas SolveBak", crate::VERSION);
    println!("threads available: {}", crate::linalg::blas2::num_threads());
    println!(
        "default workers: {} (PALLAS_THREADS {})",
        crate::parallel::default_threads(),
        std::env::var("PALLAS_THREADS")
            .map(|v| format!("= {v}"))
            .unwrap_or_else(|_| "unset".into()),
    );
    let dir = args.get("artifacts").unwrap_or("artifacts");
    match crate::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir);
            for a in &m.artifacts {
                println!(
                    "  {:<24} {:>9}  {}x{} width={}",
                    a.name, a.kind.as_str(), a.obs, a.vars, a.width
                );
            }
            match crate::runtime::Engine::new(dir) {
                Ok(eng) => println!("pjrt: {} ok", eng.platform()),
                Err(e) => println!("pjrt: unavailable ({e})"),
            }
        }
        Err(e) => println!("artifacts: none loaded ({e}) — run `make artifacts`"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(sv(&["help"])), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(sv(&["frobnicate"])), 2);
    }

    #[test]
    fn solve_small_native() {
        assert_eq!(
            run(sv(&["solve", "--obs", "200", "--vars", "10", "--backend", "bak"])),
            0
        );
    }

    #[test]
    fn features_small() {
        assert_eq!(
            run(sv(&["features", "--obs", "300", "--vars", "20", "--max-feat", "3"])),
            0
        );
    }

    #[test]
    fn bad_backend_rejected() {
        assert_eq!(run(sv(&["solve", "--backend", "gpu4000"])), 2);
    }

    #[test]
    fn backend_parsing() {
        let a = Args::parse(&sv(&["--backend", "qr"])).unwrap();
        assert_eq!(backend_of(&a).unwrap(), SolverKind::Qr);
        let a = Args::parse(&sv(&["--backend", "cgls"])).unwrap();
        assert_eq!(backend_of(&a).unwrap(), SolverKind::Cgls);
        let a = Args::parse(&sv(&[])).unwrap();
        assert_eq!(backend_of(&a).unwrap(), SolverKind::Auto);
    }

    #[test]
    fn usage_lists_every_registered_backend() {
        let u = usage();
        for s in registry() {
            assert!(u.contains(s.name()), "usage missing '{}'", s.name());
        }
    }

    #[test]
    fn solve_with_registry_backend() {
        // A comparator that only exists through the shared registry.
        assert_eq!(
            run(sv(&["solve", "--obs", "200", "--vars", "10", "--backend", "cgls"])),
            0
        );
    }

    #[test]
    fn solve_sparse_native() {
        assert_eq!(
            run(sv(&[
                "solve", "--obs", "300", "--vars", "12", "--sparse", "--density", "0.1",
                "--backend", "bak",
            ])),
            0
        );
    }

    #[test]
    fn density_alone_implies_sparse_and_dense_only_backend_still_works() {
        // qr on a sparse workload exercises the densification fallback
        // end-to-end from the CLI.
        assert_eq!(
            run(sv(&["solve", "--obs", "60", "--vars", "8", "--density", "0.2",
                     "--backend", "qr"])),
            0
        );
    }

    #[test]
    fn usage_mentions_sparse_flags() {
        let u = usage();
        assert!(u.contains("--sparse"));
        assert!(u.contains("--density"));
    }

    #[test]
    fn usage_mentions_parallel_knobs() {
        let u = usage();
        assert!(u.contains("--threads"));
        assert!(u.contains("PALLAS_THREADS"));
        assert!(u.contains("bak_par"));
        assert!(u.contains("kaczmarz_par"));
    }

    #[test]
    fn solve_with_parallel_backend_and_threads() {
        assert_eq!(
            run(sv(&[
                "solve", "--obs", "400", "--vars", "16", "--backend", "bak_par",
                "--threads", "2",
            ])),
            0
        );
    }

    #[test]
    fn solve_sparse_parallel_backend() {
        assert_eq!(
            run(sv(&[
                "solve", "--obs", "300", "--vars", "12", "--sparse", "--density", "0.2",
                "--backend", "bak_par", "--threads", "2",
            ])),
            0
        );
    }

    #[test]
    fn convert_then_solve_streamed_roundtrip() {
        let path = crate::stream::temp_chunk_path("cli_roundtrip");
        let out = path.display().to_string();
        assert_eq!(
            run(sv(&["convert", "--obs", "300", "--vars", "12", "--chunk", "5",
                     "--seed", "7", "--out", &out])),
            0
        );
        assert!(path.exists());
        assert!(sidecar_y_path(&path).exists());
        // Auto routes the file-backed solve to the streaming BAK path.
        assert_eq!(
            run(sv(&["solve", "--x-file", &out, "--mem-budget", "16384",
                     "--sweeps", "2000", "--tol", "1e-9"])),
            0
        );
        // An explicit streaming-capable hint works too.
        assert_eq!(
            run(sv(&["solve", "--x-file", &out, "--backend", "kaczmarz",
                     "--sweeps", "2000"])),
            0
        );
        let _ = std::fs::remove_file(sidecar_y_path(&path));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn convert_sparse_then_solve() {
        let path = crate::stream::temp_chunk_path("cli_sparse_convert");
        let out = path.display().to_string();
        assert_eq!(
            run(sv(&["convert", "--obs", "80", "--vars", "8", "--density", "0.2",
                     "--out", &out])),
            0
        );
        assert_eq!(run(sv(&["solve", "--x-file", &out])), 0);
        let _ = std::fs::remove_file(sidecar_y_path(&path));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn convert_requires_out() {
        assert_eq!(run(sv(&["convert", "--obs", "10", "--vars", "2"])), 2);
    }

    #[test]
    fn solve_missing_x_file_fails_cleanly() {
        assert_eq!(run(sv(&["solve", "--x-file", "/nonexistent/x.sbck"])), 2);
    }

    #[test]
    fn usage_mentions_streaming_flags() {
        let u = usage();
        assert!(u.contains("convert"));
        assert!(u.contains("--x-file"));
        assert!(u.contains("--y-file"));
        assert!(u.contains("--mem-budget"));
        assert!(u.contains("--chunk"));
    }

    #[test]
    fn stats_line_first_poll_shows_totals_then_rates() {
        let a = StatsSnap {
            requests_completed: 40.0,
            requests_failed: 1.0,
            p50_s: 0.004,
            p99_s: 0.020,
            queue_depth: 2.0,
            workers: 4.0,
            workers_busy: 3.0,
            stream_stalls: 0.0,
        };
        let first = stats_line(&a, None, 1.0);
        assert!(first.contains("req total"), "{first}");
        assert!(first.contains("40.0"), "{first}");
        assert!(first.contains("p50    4.00ms"), "{first}");
        assert!(first.contains("busy 3/4"), "{first}");
        let b = StatsSnap { requests_completed: 90.0, ..a };
        let second = stats_line(&b, Some(&a), 2.0);
        assert!(second.contains("req/s"), "{second}");
        // (90 - 40) / 2s = 25 req/s.
        assert!(second.contains("25.0"), "{second}");
    }

    #[test]
    fn stats_snap_extracts_metrics_fields() {
        let j = crate::util::json::Json::parse(
            r#"{"requests_completed": 7, "requests_failed": 2,
                "solve_latency_p50_s": 0.001, "solve_latency_p99_s": 0.1,
                "job_queue_depth": 3, "workers": 2, "workers_busy": 1,
                "stream_buffer_stalls": 5}"#,
        )
        .unwrap();
        let s = StatsSnap::from_json(&j);
        assert_eq!(s.requests_completed, 7.0);
        assert_eq!(s.requests_failed, 2.0);
        assert_eq!(s.p50_s, 0.001);
        assert_eq!(s.queue_depth, 3.0);
        assert_eq!(s.stream_stalls, 5.0);
        // Missing keys default to 0 instead of failing the dashboard.
        let empty = StatsSnap::from_json(&crate::util::json::Json::parse("{}").unwrap());
        assert_eq!(empty.workers, 0.0);
    }

    #[test]
    fn stats_polls_a_live_server() {
        let coord = Arc::new(Coordinator::start(CoordinatorConfig {
            workers: 1,
            ..CoordinatorConfig::default()
        }));
        let server = crate::coordinator::server::Server::bind(coord, 0).expect("bind");
        let addr = server.addr().to_string();
        assert_eq!(
            run(sv(&["stats", "--addr", &addr, "--interval", "0.05", "--count", "2"])),
            0
        );
        server.stop();
    }

    #[test]
    fn stats_unreachable_address_fails_cleanly() {
        // Port 1 on localhost is essentially never listening.
        assert_eq!(run(sv(&["stats", "--addr", "127.0.0.1:1", "--count", "1"])), 2);
    }

    #[test]
    fn usage_mentions_stats() {
        let u = usage();
        assert!(u.contains("stats"));
        assert!(u.contains("--addr"));
        assert!(u.contains("--interval"));
    }

    #[test]
    fn usage_mentions_robustness_knobs() {
        let u = usage();
        for knob in [
            "--deadline-ms", "--max-inflight", "--max-queue-wait-ms",
            "--degraded-sweeps", "--faults", "--retries", "PROTOCOL.md",
        ] {
            assert!(u.contains(knob), "usage missing '{knob}'");
        }
    }

    #[test]
    fn solve_with_generous_deadline_succeeds() {
        assert_eq!(
            run(sv(&["solve", "--obs", "200", "--vars", "10", "--backend", "bak",
                     "--deadline-ms", "60000"])),
            0
        );
    }

    #[test]
    fn solve_with_expired_deadline_fails_cleanly() {
        // deadline 0 expires before the job runs: typed error, exit 2.
        assert_eq!(
            run(sv(&["solve", "--obs", "200", "--vars", "10", "--backend", "bak",
                     "--deadline-ms", "0"])),
            2
        );
    }

    #[test]
    fn serve_tcp_rejects_bad_fault_spec() {
        assert_eq!(run(sv(&["serve-tcp", "--faults", "bogus=1"])), 2);
    }

    #[test]
    fn usage_mentions_cluster_knobs() {
        let u = usage();
        for knob in [
            "serve-worker", "--cluster", "--workers-addrs", "--shards",
            "--heartbeat-ms", "--worker-id",
        ] {
            assert!(u.contains(knob), "usage missing '{knob}'");
        }
    }

    #[test]
    fn cluster_config_parses_addresses_and_knobs() {
        let a = Args::parse(&sv(&[
            "--cluster", "--workers-addrs", "127.0.0.1:7450, 127.0.0.1:7451",
            "--shards", "4", "--heartbeat-ms", "200",
        ]))
        .unwrap();
        let c = cluster_config_of(&a).unwrap().expect("cluster config");
        assert_eq!(c.workers, vec!["127.0.0.1:7450".to_string(), "127.0.0.1:7451".to_string()]);
        assert_eq!(c.shards, Some(4));
        assert_eq!(c.heartbeat_ms, 200);
        // --workers-addrs alone implies --cluster; shards 0 means
        // per-request threads; heartbeat defaults on.
        let a = Args::parse(&sv(&["--workers-addrs", "127.0.0.1:7450"])).unwrap();
        let c = cluster_config_of(&a).unwrap().expect("implied cluster");
        assert_eq!(c.shards, None);
        assert_eq!(c.heartbeat_ms, 500);
        // No cluster flags at all: coordinator stays purely in-process.
        let none = cluster_config_of(&Args::parse(&sv(&[])).unwrap()).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn serve_tcp_cluster_requires_worker_addresses() {
        assert_eq!(run(sv(&["serve-tcp", "--cluster"])), 2);
    }

    #[test]
    fn serve_worker_rejects_bad_max_inflight() {
        assert_eq!(run(sv(&["serve-worker", "--max-inflight", "nope"])), 2);
    }

    #[test]
    fn threads_flag_parses_into_options() {
        let a = Args::parse(&sv(&["--threads", "8"])).unwrap();
        assert_eq!(opts_of(&a).unwrap().threads, 8);
        // Absent flag: 1 unless PALLAS_THREADS overrides (env-dependent,
        // so only assert positivity).
        let a = Args::parse(&sv(&[])).unwrap();
        assert!(opts_of(&a).unwrap().threads >= 1);
    }
}
