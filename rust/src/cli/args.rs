//! Minimal typed argument parser: `--key value`, `--flag`, positionals.

use std::collections::HashMap;

/// Parse error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ArgError {}

/// Parsed arguments: options (`--key value`), flags (`--flag`), positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

/// Option keys that take a value (everything else after `--` is a flag).
const VALUED: &[&str] = &[
    "obs", "vars", "thr", "threads", "sweeps", "tol", "seed", "backend",
    "artifacts", "scale", "samples", "max-feat", "workers", "queue",
    "requests", "out", "rows", "noise", "level", "density", "port",
    "x-file", "y-file", "mem-budget", "chunk", "addr", "interval", "count",
    "deadline-ms", "max-inflight", "max-queue-wait-ms", "degraded-sweeps",
    "faults", "retries", "journal-dir", "checkpoint-every",
    "workers-addrs", "heartbeat-ms", "shards", "worker-id",
];

impl Args {
    /// Parse a raw argv tail (without the program/subcommand names).
    pub fn parse(argv: &[String]) -> Result<Self, ArgError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if VALUED.contains(&key) {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| ArgError(format!("--{key} needs a value")))?;
                    out.opts.insert(key.to_string(), v.clone());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.pos.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_usize(v).ok_or_else(|| ArgError(format!("--{name}: bad integer '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| ArgError(format!("--{name}: bad number '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| ArgError(format!("--{name}: bad integer '{v}'"))),
        }
    }

    pub fn positionals(&self) -> &[String] {
        &self.pos
    }
}

/// Integer parser accepting scientific shorthand: "1000", "1e6", "1.5e3".
pub fn parse_usize(s: &str) -> Option<usize> {
    if let Ok(v) = s.parse::<usize>() {
        return Some(v);
    }
    if let Ok(f) = s.parse::<f64>() {
        if f >= 0.0 && f.fract() == 0.0 && f < 1e15 {
            return Some(f as usize);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_valued_options() {
        let a = Args::parse(&sv(&["--obs", "1000", "--vars", "100"])).unwrap();
        assert_eq!(a.get_usize("obs", 0).unwrap(), 1000);
        assert_eq!(a.get_usize("vars", 0).unwrap(), 100);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&sv(&["--tol=1e-5", "--quick"])).unwrap();
        assert_eq!(a.get_f64("tol", 0.0).unwrap(), 1e-5);
        assert!(a.flag("quick"));
    }

    #[test]
    fn scientific_integers() {
        assert_eq!(parse_usize("1e6"), Some(1_000_000));
        assert_eq!(parse_usize("1.5e3"), Some(1500));
        assert_eq!(parse_usize("12"), Some(12));
        assert_eq!(parse_usize("1.5"), None);
        assert_eq!(parse_usize("-3"), None);
        assert_eq!(parse_usize("abc"), None);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--obs"])).is_err());
    }

    #[test]
    fn streaming_options_are_valued() {
        let a = Args::parse(&sv(&[
            "--x-file", "/tmp/x.sbck", "--y-file", "/tmp/x.sbck.y",
            "--mem-budget", "8e6", "--chunk", "64", "--port", "7447",
        ]))
        .unwrap();
        assert_eq!(a.get("x-file"), Some("/tmp/x.sbck"));
        assert_eq!(a.get("y-file"), Some("/tmp/x.sbck.y"));
        assert_eq!(a.get_usize("mem-budget", 0).unwrap(), 8_000_000);
        assert_eq!(a.get_usize("chunk", 0).unwrap(), 64);
        assert_eq!(a.get_usize("port", 0).unwrap(), 7447);
        assert!(a.positionals().is_empty());
    }

    #[test]
    fn cluster_options_are_valued() {
        let a = Args::parse(&sv(&[
            "--workers-addrs", "127.0.0.1:7450,127.0.0.1:7451",
            "--heartbeat-ms", "200", "--shards", "4",
            "--worker-id", "w1", "--cluster",
        ]))
        .unwrap();
        assert_eq!(a.get("workers-addrs"), Some("127.0.0.1:7450,127.0.0.1:7451"));
        assert_eq!(a.get_u64("heartbeat-ms", 0).unwrap(), 200);
        assert_eq!(a.get_usize("shards", 0).unwrap(), 4);
        assert_eq!(a.get("worker-id"), Some("w1"));
        assert!(a.flag("cluster"));
    }

    #[test]
    fn stats_options_are_valued() {
        let a = Args::parse(&sv(&[
            "--addr", "127.0.0.1:7447", "--interval", "0.5", "--count", "3",
        ]))
        .unwrap();
        assert_eq!(a.get("addr"), Some("127.0.0.1:7447"));
        assert_eq!(a.get_f64("interval", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("count", 0).unwrap(), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("obs", 42).unwrap(), 42);
        assert_eq!(a.get_f64("tol", 0.5).unwrap(), 0.5);
        assert!(!a.flag("quick"));
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&sv(&["--tol", "zzz"])).unwrap();
        assert!(a.get_f64("tol", 0.0).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = Args::parse(&sv(&["file1", "--quick", "file2"])).unwrap();
        assert_eq!(a.positionals(), &["file1".to_string(), "file2".to_string()]);
    }
}
