//! Gaussian elimination with partial pivoting — the classical square-system
//! solver the paper's introduction positions against (and reports as faster
//! than BAK for square systems in §7).

use super::qr::SolveError;
use crate::linalg::Mat;

/// Solve the square system A a = y by LU with partial pivoting.
pub fn gauss_solve(a: &Mat, y: &[f32]) -> Result<Vec<f32>, SolveError> {
    let (m, n) = a.shape();
    if m != n {
        return Err(SolveError::Shape(format!("gauss_solve needs square, got {m}x{n}")));
    }
    if y.len() != n {
        return Err(SolveError::Shape(format!("rhs len {} != {n}", y.len())));
    }
    // Work row-major for the elimination (row swaps are the hot operation).
    let mut w: Vec<Vec<f32>> = (0..n).map(|i| a.row(i)).collect();
    let mut b = y.to_vec();

    for k in 0..n {
        // Partial pivot: largest |w[i][k]|, i >= k.
        let (piv, pmax) = (k..n)
            .map(|i| (i, w[i][k].abs()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if pmax < 1e-12 {
            return Err(SolveError::RankDeficient(k));
        }
        w.swap(k, piv);
        b.swap(k, piv);
        let pivot = w[k][k];
        let (head, tail) = w.split_at_mut(k + 1);
        let row_k = &head[k];
        for (off, row_i) in tail.iter_mut().enumerate() {
            let factor = row_i[k] / pivot;
            if factor != 0.0 {
                for j in k..n {
                    row_i[j] -= factor * row_k[j];
                }
                b[k + 1 + off] -= factor * b[k];
            }
            row_i[k] = 0.0;
        }
    }
    // Back substitution.
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for (j, &xj) in x.iter().enumerate().skip(i + 1) {
            s -= w[i][j] * xj;
        }
        x[i] = s / w[i][i];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn identity_solve() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { 1.0 } else { 0.0 });
        let y = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(gauss_solve(&a, &y).unwrap(), y);
    }

    #[test]
    fn known_2x2() {
        // [2 1; 1 3] a = [3; 5] -> a = (4/5, 7/5)
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let x = gauss_solve(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-5);
        assert!((x[1] - 1.4).abs() < 1e-5);
    }

    #[test]
    fn random_systems_recover_truth() {
        let mut rng = Rng::seed(30);
        for n in [3, 10, 50, 100] {
            let a = Mat::randn(&mut rng, n, n);
            let t: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let y = a.matvec(&t);
            let x = gauss_solve(&a, &y).unwrap();
            assert!(rel_l2(&x, &t) < 1e-2, "n={n} err={}", rel_l2(&x, &t));
        }
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        // a11 == 0 forces a row swap.
        let a = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = gauss_solve(&a, &[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(matches!(gauss_solve(&a, &[1.0, 2.0]), Err(SolveError::RankDeficient(_))));
    }

    #[test]
    fn non_square_rejected() {
        let a = Mat::zeros(3, 2);
        assert!(matches!(gauss_solve(&a, &[0.0; 3]), Err(SolveError::Shape(_))));
    }
}
