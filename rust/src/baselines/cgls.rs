//! CGLS: conjugate gradient on the normal equations.
//!
//! The standard iterative least-squares method in the same
//! O(obs*vars)-per-iteration class as SolveBak — included so the ablation
//! benches can place the paper's algorithm against the textbook comparator
//! it never cites (CG converges in O(sqrt(cond)) iterations vs. CD's
//! O(cond), which is the honest context for Table 1's speedups).

use crate::linalg::{blas1, Mat};

/// Result of a CGLS run.
#[derive(Clone, Debug)]
pub struct CglsReport {
    pub a: Vec<f32>,
    /// Squared residual ||y - X a||^2 after each iteration.
    pub history: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Minimise ||y - X a|| by CGLS.
///
/// Stops when the *relative* residual-norm improvement of the normal-
/// equations residual drops below `tol`, or after `max_iter` iterations.
pub fn cgls_solve(x: &Mat, y: &[f32], max_iter: usize, tol: f64) -> CglsReport {
    cgls_solve_probed(x, y, max_iter, tol, &crate::obs::ProbeHandle::none())
}

/// [`cgls_solve`] with a per-iteration convergence probe (one CGLS
/// iteration counts as one "sweep" for the probe).
pub fn cgls_solve_probed(
    x: &Mat,
    y: &[f32],
    max_iter: usize,
    tol: f64,
    probe: &crate::obs::ProbeHandle,
) -> CglsReport {
    let (m, n) = x.shape();
    assert_eq!(y.len(), m);
    let mut a = vec![0.0f32; n];
    let mut r = y.to_vec(); // residual y - X a
    let mut s = x.matvec_t(&r); // normal-equations residual Xᵀ r
    let mut p = s.clone();
    let mut gamma = blas1::sum_sq_f64(&s);
    let gamma0 = gamma;
    let mut history = Vec::with_capacity(max_iter);
    let mut converged = false;
    let mut iterations = 0;
    let t0 = std::time::Instant::now();

    for _ in 0..max_iter {
        iterations += 1;
        let q = x.matvec(&p); // X p
        let qq = blas1::sum_sq_f64(&q);
        if qq == 0.0 {
            converged = true;
            break;
        }
        let alpha = (gamma / qq) as f32;
        blas1::axpy(alpha, &p, &mut a);
        blas1::axpy(-alpha, &q, &mut r);
        let r2 = blas1::sum_sq_f64(&r);
        history.push(r2);
        probe.observe(iterations, r2, t0);
        s = x.matvec_t(&r);
        let gamma_new = blas1::sum_sq_f64(&s);
        if gamma_new <= tol * tol * gamma0 {
            converged = true;
            break;
        }
        let beta = (gamma_new / gamma) as f32;
        for (pi, &si) in p.iter_mut().zip(&s) {
            *pi = si + beta * *pi;
        }
        gamma = gamma_new;
    }
    CglsReport { a, history, iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn exact_recovery_tall() {
        let mut rng = Rng::seed(50);
        let x = Mat::randn(&mut rng, 200, 20);
        let t: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&t);
        let rep = cgls_solve(&x, &y, 100, 1e-8);
        assert!(rep.converged);
        assert!(rel_l2(&rep.a, &t) < 1e-3);
    }

    #[test]
    fn converges_in_at_most_n_iterations_well_conditioned() {
        // Exact-arithmetic CG terminates in <= n steps; with f32 rounding
        // and a well-conditioned Gaussian matrix it should be close.
        let mut rng = Rng::seed(51);
        let x = Mat::randn(&mut rng, 300, 10);
        let t: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&t);
        let rep = cgls_solve(&x, &y, 40, 1e-7);
        assert!(rep.converged, "iterations={}", rep.iterations);
        assert!(rep.iterations <= 30);
    }

    #[test]
    fn history_monotone() {
        let mut rng = Rng::seed(52);
        let x = Mat::randn(&mut rng, 100, 30);
        let y: Vec<f32> = (0..100).map(|_| rng.normal_f32()).collect();
        let rep = cgls_solve(&x, &y, 30, 0.0);
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6));
        }
    }

    #[test]
    fn noisy_matches_qr() {
        let mut rng = Rng::seed(53);
        let x = Mat::randn(&mut rng, 150, 12);
        let y: Vec<f32> = (0..150).map(|_| rng.normal_f32()).collect();
        let rep = cgls_solve(&x, &y, 200, 1e-9);
        let a_qr = crate::baselines::qr::lstsq_qr(&x, &y).unwrap();
        assert!(rel_l2(&rep.a, &a_qr) < 1e-2);
    }

    #[test]
    fn probed_variant_matches_history() {
        let mut rng = Rng::seed(55);
        let x = Mat::randn(&mut rng, 120, 10);
        let y: Vec<f32> = (0..120).map(|_| rng.normal_f32()).collect();
        let probe = crate::obs::RingProbe::new(256);
        let handle = crate::obs::ProbeHandle::new(probe.clone());
        let rep = cgls_solve_probed(&x, &y, 30, 0.0, &handle);
        let snap = probe.snapshot();
        assert_eq!(snap.len(), rep.history.len());
        for (p, &h) in snap.iter().zip(&rep.history) {
            assert!((p.residual_norm - h.sqrt()).abs() < 1e-12);
        }
        // The unprobed wrapper is the same computation.
        let plain = cgls_solve(&x, &y, 30, 0.0);
        assert_eq!(rep.a, plain.a);
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let mut rng = Rng::seed(54);
        let x = Mat::randn(&mut rng, 20, 5);
        let rep = cgls_solve(&x, &[0.0; 20], 10, 1e-8);
        assert!(rep.a.iter().all(|&v| v == 0.0));
        assert!(rep.converged);
    }
}
