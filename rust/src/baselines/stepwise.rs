//! Forward stepwise regression — the Figure-2 baseline.
//!
//! Classic forward selection: at each round, try EVERY remaining feature by
//! refitting the full least-squares model with it added, and keep the one
//! with the lowest residual. Cost per round is O(vars * k^2 * obs) — the
//! expensive exhaustive search that SolveBakF's one-pass scoring undercuts;
//! Figure 2's speedup is exactly this gap.

use super::cholesky::solve_normal_equations;
use crate::linalg::{blas1, residual, Mat};

/// Outcome of stepwise selection.
#[derive(Clone, Debug)]
pub struct StepwiseReport {
    /// Selected feature indices, in selection order.
    pub selected: Vec<usize>,
    /// Coefficients of the final refit (aligned with `selected`).
    pub coeffs: Vec<f32>,
    /// Squared residual after each round.
    pub history: Vec<f64>,
}

/// Forward stepwise selection of up to `max_feat` features.
pub fn stepwise_select(x: &Mat, y: &[f32], max_feat: usize) -> StepwiseReport {
    let vars = x.cols();
    let max_feat = max_feat.min(vars);
    let mut selected: Vec<usize> = Vec::with_capacity(max_feat);
    let mut coeffs: Vec<f32> = Vec::new();
    let mut history = Vec::with_capacity(max_feat);

    for _ in 0..max_feat {
        let mut best: Option<(usize, f64, Vec<f32>)> = None;
        for j in 0..vars {
            if selected.contains(&j) {
                continue;
            }
            let mut trial = selected.clone();
            trial.push(j);
            let xs = x.select_cols(&trial);
            // Tiny ridge: trial sets can be collinear mid-search.
            let Ok(a) = solve_normal_equations(&xs, y, 1e-6) else {
                continue;
            };
            let e = residual(&xs, y, &a);
            let r2 = blas1::sum_sq_f64(&e);
            if best.as_ref().is_none_or(|(_, b, _)| r2 < *b) {
                best = Some((j, r2, a));
            }
        }
        let Some((j, r2, a)) = best else { break };
        selected.push(j);
        coeffs = a;
        history.push(r2);
    }
    StepwiseReport { selected, coeffs, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn planted(seed: u64, obs: usize, vars: usize, support: &[(usize, f32)]) -> (Mat, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let mut y = vec![0.0f32; obs];
        for &(j, w) in support {
            blas1::axpy(w, x.col(j), &mut y);
        }
        (x, y)
    }

    #[test]
    fn recovers_planted_support() {
        let (x, y) = planted(60, 300, 20, &[(3, 2.0), (11, -1.5), (17, 0.7)]);
        let rep = stepwise_select(&x, &y, 3);
        let mut s = rep.selected.clone();
        s.sort_unstable();
        assert_eq!(s, vec![3, 11, 17]);
        assert!(rep.history[2] < 1e-4 * blas1::sum_sq_f64(&y));
    }

    #[test]
    fn selection_order_by_strength() {
        // The strongest feature must be picked first.
        let (x, y) = planted(61, 400, 15, &[(2, 5.0), (9, 0.5)]);
        let rep = stepwise_select(&x, &y, 2);
        assert_eq!(rep.selected[0], 2);
        assert_eq!(rep.selected[1], 9);
    }

    #[test]
    fn history_monotone_nonincreasing() {
        let mut rng = Rng::seed(62);
        let x = Mat::randn(&mut rng, 100, 12);
        let y: Vec<f32> = (0..100).map(|_| rng.normal_f32()).collect();
        let rep = stepwise_select(&x, &y, 6);
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6));
        }
    }

    #[test]
    fn max_feat_capped_at_vars() {
        let mut rng = Rng::seed(63);
        let x = Mat::randn(&mut rng, 30, 4);
        let y: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
        let rep = stepwise_select(&x, &y, 10);
        assert_eq!(rep.selected.len(), 4);
    }

    #[test]
    fn coeffs_align_with_selected() {
        let (x, y) = planted(64, 200, 10, &[(1, 3.0)]);
        let rep = stepwise_select(&x, &y, 1);
        assert_eq!(rep.selected, vec![1]);
        assert_eq!(rep.coeffs.len(), 1);
        assert!((rep.coeffs[0] - 3.0).abs() < 1e-2);
    }
}
