//! Householder-QR least squares — the paper's "LAPACK" baseline.
//!
//! Julia's `x \ y` for a non-square dense system calls LAPACK `gels`,
//! which factors X = QR with Householder reflectors and solves
//! R a = Qᵀ y. This module reimplements that path (without pivoting; the
//! bench workloads are dense Gaussian, numerically full-rank).
//! Cost: O(obs * vars^2) flops — the 2-to-3-orders-of-magnitude gap to
//! SolveBak's O(obs * vars) per sweep is exactly what Table 1 measures.

use crate::linalg::{blas1, Mat};

/// Error type for the direct solvers.
#[derive(Debug, Clone, PartialEq)]
pub enum SolveError {
    /// Matrix is (numerically) rank-deficient at the given column.
    RankDeficient(usize),
    /// Dimension mismatch.
    Shape(String),
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::RankDeficient(j) => write!(f, "rank deficient at column {j}"),
            SolveError::Shape(s) => write!(f, "shape error: {s}"),
        }
    }
}

impl std::error::Error for SolveError {}

/// In-place Householder QR of a copy of `x`; returns (packed factors, taus).
///
/// Factors are stored LAPACK-style: R in the upper triangle, the essential
/// part of each reflector v_j below the diagonal (v_j[j] == 1 implicit).
pub fn householder_qr(x: &Mat) -> (Mat, Vec<f32>) {
    let (m, n) = x.shape();
    assert!(m >= n, "householder_qr requires obs >= vars (tall); got {m}x{n}");
    let mut a = x.clone();
    let mut taus = vec![0.0f32; n];
    for j in 0..n {
        // Build the reflector for column j, rows j..m.
        let (head, tail_norm_sq) = {
            let col = a.col(j);
            let head = col[j];
            let t: f32 = blas1::nrm2_sq(&col[j + 1..]);
            (head, t)
        };
        let norm = (head * head + tail_norm_sq).sqrt();
        if norm == 0.0 {
            taus[j] = 0.0;
            continue;
        }
        let alpha = if head >= 0.0 { -norm } else { norm };
        let v0 = head - alpha;
        // tau = (alpha - head)/alpha per LAPACK convention with v0 scaled to 1.
        let tau = -v0 / alpha;
        // Scale tail by 1/v0 so the stored reflector has implicit v[j]=1.
        {
            let col = a.col_mut(j);
            col[j] = alpha; // R diagonal
            if v0 != 0.0 {
                let inv = 1.0 / v0;
                for v in col[j + 1..].iter_mut() {
                    *v *= inv;
                }
            }
        }
        taus[j] = tau;
        if tau == 0.0 {
            continue;
        }
        // Apply (I - tau v vᵀ) to the remaining columns.
        for k in j + 1..n {
            let w = {
                let vj = &a.col(j)[j + 1..];
                let ck = a.col(k);
                ck[j] + blas1::dot(vj, &ck[j + 1..])
            };
            let tw = tau * w;
            // Split borrow: copy the reflector tail (small) to avoid aliasing.
            let vj: Vec<f32> = a.col(j)[j + 1..].to_vec();
            let ck = a.col_mut(k);
            ck[j] -= tw;
            blas1::axpy(-tw, &vj, &mut ck[j + 1..]);
        }
    }
    (a, taus)
}

/// Apply Qᵀ (from packed factors) to a vector.
pub fn apply_qt(factors: &Mat, taus: &[f32], y: &[f32]) -> Vec<f32> {
    let (m, n) = factors.shape();
    assert_eq!(y.len(), m);
    let mut out = y.to_vec();
    for j in 0..n {
        let tau = taus[j];
        if tau == 0.0 {
            continue;
        }
        let vj = &factors.col(j)[j + 1..];
        let w = out[j] + blas1::dot(vj, &out[j + 1..]);
        let tw = tau * w;
        out[j] -= tw;
        blas1::axpy(-tw, vj, &mut out[j + 1..]);
    }
    out
}

/// Back-substitution on the R factor: solves R a = b[..n].
pub fn solve_upper_triangular(factors: &Mat, b: &[f32]) -> Result<Vec<f32>, SolveError> {
    let n = factors.cols();
    // Relative rank threshold (f32): diagonal entries this far below the
    // largest one are numerically zero.
    let dmax = (0..n).map(|j| factors.get(j, j).abs()).fold(0.0f32, f32::max);
    let thresh = dmax * 1e-6 + f32::MIN_POSITIVE;
    let mut a = vec![0.0f32; n];
    for j in (0..n).rev() {
        let rjj = factors.get(j, j);
        if rjj.abs() < thresh {
            return Err(SolveError::RankDeficient(j));
        }
        let mut s = b[j];
        // s -= sum_{k>j} R[j,k] a[k]; R[j,k] is factors[(j,k)], k>j.
        for (k, &ak) in a.iter().enumerate().skip(j + 1) {
            s -= factors.get(j, k) * ak;
        }
        a[j] = s / rjj;
    }
    Ok(a)
}

/// Least squares via Householder QR: minimises ||y - X a||_2 for tall X.
///
/// For wide systems (vars > obs) the minimum-norm problem is solved via QR
/// of Xᵀ: a = Qᵀ (Rᵀ)^{-1}... i.e. a = Q z with Rᵀ z = y.
pub fn lstsq_qr(x: &Mat, y: &[f32]) -> Result<Vec<f32>, SolveError> {
    let (m, n) = x.shape();
    if y.len() != m {
        return Err(SolveError::Shape(format!("y len {} != obs {m}", y.len())));
    }
    if m >= n {
        let (f, taus) = householder_qr(x);
        let qty = apply_qt(&f, &taus, y);
        solve_upper_triangular(&f, &qty)
    } else {
        // Wide: minimum-norm solution through QR of the transpose.
        let xt = x.transposed(); // (n, m), tall
        let (f, taus) = householder_qr(&xt);
        // X = Rᵀ Qᵀ (from Xᵀ = Q R). Solve Rᵀ z = y (forward substitution),
        // then a = Q [z; 0].
        let dmax = (0..m).map(|i| f.get(i, i).abs()).fold(0.0f32, f32::max);
        let thresh = dmax * 1e-6 + f32::MIN_POSITIVE;
        let mut z = vec![0.0f32; m];
        for i in 0..m {
            let rii = f.get(i, i);
            if rii.abs() < thresh {
                return Err(SolveError::RankDeficient(i));
            }
            let mut s = y[i];
            for (k, &zk) in z.iter().enumerate().take(i) {
                // (Rᵀ)[i,k] = R[k,i]
                s -= f.get(k, i) * zk;
            }
            z[i] = s / rii;
        }
        // a = Q [z; 0]: apply reflectors in reverse order.
        let mut a = vec![0.0f32; n];
        a[..m].copy_from_slice(&z);
        for j in (0..m).rev() {
            let tau = taus[j];
            if tau == 0.0 {
                continue;
            }
            let vj = &f.col(j)[j + 1..];
            let w = a[j] + blas1::dot(vj, &a[j + 1..]);
            let tw = tau * w;
            a[j] -= tw;
            blas1::axpy(-tw, vj, &mut a[j + 1..]);
        }
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::residual;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn qr_reconstructs_r_diagonal_nonzero() {
        let mut rng = Rng::seed(20);
        let x = Mat::randn(&mut rng, 30, 10);
        let (f, _t) = householder_qr(&x);
        for j in 0..10 {
            assert!(f.get(j, j).abs() > 1e-4);
        }
    }

    #[test]
    fn qt_preserves_norm() {
        let mut rng = Rng::seed(21);
        let x = Mat::randn(&mut rng, 25, 8);
        let (f, t) = householder_qr(&x);
        let y: Vec<f32> = (0..25).map(|_| rng.normal_f32()).collect();
        let qty = apply_qt(&f, &t, &y);
        let n1 = blas1::nrm2(&y);
        let n2 = blas1::nrm2(&qty);
        assert!((n1 - n2).abs() < 1e-3 * n1, "orthogonality: {n1} vs {n2}");
    }

    #[test]
    fn exact_square_system() {
        let mut rng = Rng::seed(22);
        let x = Mat::randn(&mut rng, 12, 12);
        let a_true: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        let a = lstsq_qr(&x, &y).unwrap();
        assert!(rel_l2(&a, &a_true) < 1e-3);
    }

    #[test]
    fn tall_consistent_system_recovers_truth() {
        let mut rng = Rng::seed(23);
        let x = Mat::randn(&mut rng, 100, 20);
        let a_true: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        let a = lstsq_qr(&x, &y).unwrap();
        assert!(rel_l2(&a, &a_true) < 1e-4);
    }

    #[test]
    fn tall_noisy_residual_is_orthogonal_to_columns() {
        // Least-squares optimality: Xᵀ e == 0.
        let mut rng = Rng::seed(24);
        let x = Mat::randn(&mut rng, 80, 10);
        let y: Vec<f32> = (0..80).map(|_| rng.normal_f32()).collect();
        let a = lstsq_qr(&x, &y).unwrap();
        let e = residual(&x, &y, &a);
        let g = x.matvec_t(&e);
        for (j, v) in g.iter().enumerate() {
            assert!(v.abs() < 2e-3, "column {j} not orthogonal: {v}");
        }
    }

    #[test]
    fn wide_system_interpolates() {
        let mut rng = Rng::seed(25);
        let x = Mat::randn(&mut rng, 15, 60);
        let y: Vec<f32> = (0..15).map(|_| rng.normal_f32()).collect();
        let a = lstsq_qr(&x, &y).unwrap();
        let e = residual(&x, &y, &a);
        assert!(blas1::nrm2(&e) < 1e-3, "wide system must be satisfied exactly");
    }

    #[test]
    fn wide_solution_is_minimum_norm() {
        // Min-norm solution lies in the row space: a = Xᵀ w for some w.
        // Equivalent check: any null-space perturbation increases the norm;
        // compare against the normal-equations min-norm formula
        // a = Xᵀ (X Xᵀ)^{-1} y on a small instance.
        let mut rng = Rng::seed(26);
        let x = Mat::randn(&mut rng, 6, 20);
        let y: Vec<f32> = (0..6).map(|_| rng.normal_f32()).collect();
        let a = lstsq_qr(&x, &y).unwrap();
        // Gram (X Xᵀ) solve via gauss.
        let xxt = crate::linalg::blas3::gemm_tn(&x.transposed(), &x.transposed());
        let w = crate::baselines::gauss::gauss_solve(&xxt, &y).unwrap();
        let a_min = x.matvec_t(&w);
        assert!(rel_l2(&a, &a_min) < 1e-2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let x = Mat::zeros(5, 2);
        assert!(matches!(lstsq_qr(&x, &[1.0; 4]), Err(SolveError::Shape(_))));
    }

    #[test]
    fn rank_deficient_detected() {
        // Two identical columns.
        let mut rng = Rng::seed(27);
        let mut x = Mat::randn(&mut rng, 10, 3);
        let c0 = x.col(0).to_vec();
        x.col_mut(1).copy_from_slice(&c0);
        let y: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
        assert!(matches!(lstsq_qr(&x, &y), Err(SolveError::RankDeficient(_))));
    }
}
