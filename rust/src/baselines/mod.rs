//! The comparator algorithms of the paper's evaluation, implemented from
//! scratch (no LAPACK/BLAS in the offline environment — see DESIGN.md
//! §Substitutions):
//!
//! * [`qr`] — Householder-QR least squares: the stand-in for Julia's
//!   LAPACK `\` (which uses QR for non-square systems). This is the
//!   "LAPACK" column of Table 1.
//! * [`cholesky`] — normal-equations solve (Xᵀ X a = Xᵀ y).
//! * [`gauss`] — Gaussian elimination with partial pivoting (square
//!   systems; §1's classical reference point).
//! * [`cgls`] — conjugate-gradient on the normal equations: the standard
//!   iterative comparator in the same O(mn)-per-iteration class as
//!   SolveBak (used by the ablation benches).
//! * [`stepwise`] — forward stepwise regression, the Figure-2 baseline.
//!
//! The free functions here are stable thin wrappers; every comparator is
//! also addressable through the uniform [`crate::api::Solver`] trait
//! (`SolverKind::{Qr, Cholesky, Gauss, Cgls}`), which adds shape checking
//! and typed [`crate::api::SolverError`]s.

pub mod qr;
pub mod cholesky;
pub mod gauss;
pub mod cgls;
pub mod stepwise;

pub use cgls::cgls_solve;
pub use cholesky::{cholesky_factor, cholesky_solve, solve_normal_equations};
pub use gauss::gauss_solve;
pub use qr::lstsq_qr;
pub use stepwise::stepwise_select;
