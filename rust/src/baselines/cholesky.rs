//! Normal-equations solver: a = (Xᵀ X)^{-1} Xᵀ y via Cholesky.
//!
//! Used (a) as a Table-1 comparator for tall systems, and (b) by
//! SolveBakF's exact least-squares refit on the selected columns
//! (Algorithm 3 line 7), where the k x k Gram system is tiny.

use super::qr::SolveError;
use crate::linalg::{blas3, Mat};

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
pub fn cholesky_factor(g: &Mat) -> Result<Mat, SolveError> {
    let (m, n) = g.shape();
    if m != n {
        return Err(SolveError::Shape(format!("cholesky needs square, got {m}x{n}")));
    }
    let mut l = Mat::zeros(n, n);
    for j in 0..n {
        // Diagonal.
        let mut d = g.get(j, j);
        for k in 0..j {
            let ljk = l.get(j, k);
            d -= ljk * ljk;
        }
        if d <= 0.0 {
            return Err(SolveError::RankDeficient(j));
        }
        let ljj = d.sqrt();
        l.set(j, j, ljj);
        // Below-diagonal column.
        for i in j + 1..n {
            let mut s = g.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            l.set(i, j, s / ljj);
        }
    }
    Ok(l)
}

/// Solve L Lᵀ a = b given the lower factor L.
pub fn cholesky_solve(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.cols();
    debug_assert_eq!(b.len(), n);
    // Forward: L z = b.
    let mut z = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i];
        for (k, &zk) in z.iter().enumerate().take(i) {
            s -= l.get(i, k) * zk;
        }
        z[i] = s / l.get(i, i);
    }
    // Backward: Lᵀ a = z.
    let mut a = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for (k, &ak) in a.iter().enumerate().skip(i + 1) {
            s -= l.get(k, i) * ak;
        }
        a[i] = s / l.get(i, i);
    }
    a
}

/// Least squares through the normal equations (with a tiny ridge for
/// numerical safety on near-collinear workloads).
pub fn solve_normal_equations(x: &Mat, y: &[f32], ridge: f32) -> Result<Vec<f32>, SolveError> {
    if y.len() != x.rows() {
        return Err(SolveError::Shape(format!("y len {} != obs {}", y.len(), x.rows())));
    }
    let mut g = blas3::gram(x);
    if ridge > 0.0 {
        for j in 0..g.cols() {
            *g.get_mut(j, j) += ridge;
        }
    }
    let rhs = x.matvec_t(y);
    let l = cholesky_factor(&g)?;
    Ok(cholesky_solve(&l, &rhs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::residual;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn factor_known_matrix() {
        // G = [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let g = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky_factor(&g).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-6);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-6);
        assert!((l.get(1, 1) - 2.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(l.get(0, 1), 0.0);
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::seed(40);
        let x = Mat::randn(&mut rng, 30, 8);
        let g = blas3::gram(&x);
        let l = cholesky_factor(&g).unwrap();
        // L Lᵀ == G.
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0f32;
                for k in 0..8 {
                    s += l.get(i, k) * l.get(j, k);
                }
                assert!((s - g.get(i, j)).abs() < 2e-2 * (1.0 + g.get(i, j).abs()));
            }
        }
    }

    #[test]
    fn not_positive_definite_detected() {
        let g = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalue -1
        assert!(matches!(cholesky_factor(&g), Err(SolveError::RankDeficient(_))));
    }

    #[test]
    fn normal_equations_match_qr_on_tall() {
        let mut rng = Rng::seed(41);
        let x = Mat::randn(&mut rng, 120, 15);
        let y: Vec<f32> = (0..120).map(|_| rng.normal_f32()).collect();
        let a_ne = solve_normal_equations(&x, &y, 0.0).unwrap();
        let a_qr = crate::baselines::qr::lstsq_qr(&x, &y).unwrap();
        assert!(rel_l2(&a_ne, &a_qr) < 1e-2);
    }

    #[test]
    fn exact_recovery() {
        let mut rng = Rng::seed(42);
        let x = Mat::randn(&mut rng, 60, 10);
        let t: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&t);
        let a = solve_normal_equations(&x, &y, 0.0).unwrap();
        assert!(rel_l2(&a, &t) < 1e-3);
    }

    #[test]
    fn residual_orthogonality() {
        let mut rng = Rng::seed(43);
        let x = Mat::randn(&mut rng, 50, 6);
        let y: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let a = solve_normal_equations(&x, &y, 0.0).unwrap();
        let e = residual(&x, &y, &a);
        for v in x.matvec_t(&e) {
            assert!(v.abs() < 5e-3, "Xᵀe = {v}");
        }
    }

    #[test]
    fn ridge_shrinks_solution() {
        let mut rng = Rng::seed(44);
        let x = Mat::randn(&mut rng, 40, 5);
        let y: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let a0 = solve_normal_equations(&x, &y, 0.0).unwrap();
        let a1 = solve_normal_equations(&x, &y, 100.0).unwrap();
        let n0: f32 = a0.iter().map(|v| v * v).sum();
        let n1: f32 = a1.iter().map(|v| v * v).sum();
        assert!(n1 < n0);
    }
}
