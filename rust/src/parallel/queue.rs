//! Bounded MPMC job queue with blocking backpressure, built on
//! `Mutex + Condvar` (the offline registry has no crossbeam-channel).
//!
//! This is the injector behind [`super::pool::Executor`] and the
//! coordinator's submit queue (which re-exports it as
//! `coordinator::queue` for compatibility).
//!
//! Semantics:
//! * `push` blocks while the queue is at capacity (backpressure to
//!   producers), fails once the queue is closed.
//! * `pop` blocks while empty, returns `None` once closed AND drained.
//! * `close` wakes everyone; producers error, consumers drain.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded blocking queue.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

/// Error returned by `push` on a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

impl<T> BoundedQueue<T> {
    /// Create with the given capacity (>= 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns Err(Closed) if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), Closed> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(Closed);
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push attempt. Err(item) if full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.capacity {
            return Err(item);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Pop everything currently queued without blocking.
    pub fn drain_now(&self) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        let out: Vec<T> = st.items.drain(..).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Close the queue: producers fail, consumers drain then get None.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Current depth (racy; for metrics only).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(1);
        q.push(1).unwrap();
        assert_eq!(q.try_push(2), Err(2));
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(2).is_ok());
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(9), Err(Closed));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2)); // blocks
        std::thread::sleep(Duration::from_millis(30));
        assert!(!h.is_finished(), "push should be blocked on full queue");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(BoundedQueue::<u32>::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(30));
        assert!(!h.is_finished(), "pop should be blocked on empty queue");
        q.push(42).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn multi_producer_multi_consumer_conserves_items() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut want: Vec<i32> = (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want);
    }

    #[test]
    fn drain_now_empties() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let d = q.drain_now();
        assert_eq!(d, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }
}
