//! Block-parallel solver variants: the paper's column-action iteration is
//! "by definition, vectorized" — these run it on real threads.
//!
//! * [`solve_bak_par`] / [`solve_bak_par_csc`] — column-partitioned
//!   SolveBak: the columns are split into `opts.threads` contiguous
//!   blocks; each block runs one paper-style inner sweep concurrently
//!   against its own copy of the shared residual (fresh within the block,
//!   stale across blocks), then the blocks sync: coefficient deltas merge
//!   additively (blocks own disjoint columns) and the shared residual is
//!   rebuilt row-parallel from the per-block locals,
//!   `e' = Σ_b e_b − (B−1)·e`, in f64. Cross-block staleness carries the
//!   same §6 caveat as SolveBakP's in-block staleness — correlated columns
//!   split across blocks can overshoot — and the same guard applies: the
//!   residual-tolerance loop with stall/divergence detection.
//! * [`solve_kaczmarz_par`] / [`solve_kaczmarz_par_csr`] — row-partitioned
//!   randomized Kaczmarz with averaging sync (the parallel RK scheme of
//!   Fliege 2012 / Needell et al.): each block projects onto its own rows
//!   (norm-weighted sampling restricted to the block), and the iterates
//!   merge as a row-norm-mass-weighted average every sweep.
//! * [`solve_bak_multi_par`] / [`solve_bak_multi_par_csc`] — multi-RHS
//!   SolveBak: column norms are computed ONCE and shared by every worker;
//!   right-hand sides are chunked across threads and each chunk walks the
//!   matrix (dense columns or CSC traversal) once per sweep for all of its
//!   systems.
//!
//! Determinism: block structure is derived from `(shape, opts.threads)`
//! via [`super::pool::partition_ranges`], anything randomized (Kaczmarz
//! row sampling, the Shuffled column order) seeds off
//! `(opts.seed, block, sweep)` via [`super::pool::stream_seed`], and every
//! merge folds in block order — so results are identical across runs for a
//! fixed `(seed, threads)`, no matter how the OS schedules the workers.
//! With `threads = 1` and the default cyclic column order the BAK variants
//! reduce to the serial algorithms bit-for-bit (Shuffled uses the
//! per-(block, sweep) RNG streams above, so its permutation sequence
//! differs from the serial solver's single persistent stream).
//!
//! Dense and sparse storage share the same schedulers through the small
//! [`ColAccess`]/[`RowAccess`] traits below; the per-step cost is
//! O(obs)/O(vars) dense and O(nnz(col))/O(nnz(row)) sparse, exactly like
//! the serial pairs.

use crate::linalg::{blas1, Mat};
use crate::solver::{ColumnOrder, SolveOptions, SolveReport, StopReason};
use crate::sparse::{sp_axpy_into_dense, sp_cd_step, sp_dot_dense, CscMat, CsrMat};
use crate::util::rng::Rng;

use super::pool::{par_for_disjoint, par_map_chunks, partition_ranges, stream_seed};

/// Column access shared by the dense and CSC block schedulers.
trait ColAccess: Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// 1/<x_j,x_j> per column, zero columns mapped to 0.
    fn colnorms_inv_vec(&self) -> Vec<f32>;
    /// The Algorithm-1 inner step: `da = <x_j, e> * cninv; e -= da * x_j`.
    fn cd_step(&self, j: usize, e: &mut [f32], cninv: f32) -> f32;
}

impl ColAccess for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }

    fn cols(&self) -> usize {
        Mat::cols(self)
    }

    fn colnorms_inv_vec(&self) -> Vec<f32> {
        crate::solver::colnorms_inv(self)
    }

    fn cd_step(&self, j: usize, e: &mut [f32], cninv: f32) -> f32 {
        blas1::cd_step(self.col(j), e, cninv)
    }
}

impl ColAccess for CscMat {
    fn rows(&self) -> usize {
        CscMat::rows(self)
    }

    fn cols(&self) -> usize {
        CscMat::cols(self)
    }

    fn colnorms_inv_vec(&self) -> Vec<f32> {
        crate::sparse::solve::colnorms_inv_csc(self)
    }

    fn cd_step(&self, j: usize, e: &mut [f32], cninv: f32) -> f32 {
        let (idx, vals) = self.col(j);
        sp_cd_step(idx, vals, e, cninv)
    }
}

/// Row access shared by the dense and CSR Kaczmarz schedulers.
trait RowAccess: Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn row_norms_sq_vec(&self) -> Vec<f32>;
    /// `<row_i, a>`.
    fn dot_row(&self, i: usize, a: &[f32]) -> f32;
    /// `a += scale * row_i`.
    fn axpy_row(&self, i: usize, scale: f32, a: &mut [f32]);
    /// `y - X a`.
    fn residual_vec(&self, y: &[f32], a: &[f32]) -> Vec<f32>;
}

impl RowAccess for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }

    fn cols(&self) -> usize {
        Mat::cols(self)
    }

    fn row_norms_sq_vec(&self) -> Vec<f32> {
        // One column-major pass (sequential reads), as in solve_kaczmarz.
        let mut out = vec![0.0f32; Mat::rows(self)];
        for j in 0..Mat::cols(self) {
            for (rn, &v) in out.iter_mut().zip(self.col(j)) {
                *rn = v.mul_add(v, *rn);
            }
        }
        out
    }

    fn dot_row(&self, i: usize, a: &[f32]) -> f32 {
        blas1::dot_strided(&self.as_slice()[i..], Mat::rows(self), a)
    }

    fn axpy_row(&self, i: usize, scale: f32, a: &mut [f32]) {
        blas1::axpy_strided(scale, &self.as_slice()[i..], Mat::rows(self), a)
    }

    fn residual_vec(&self, y: &[f32], a: &[f32]) -> Vec<f32> {
        crate::linalg::residual(self, y, a)
    }
}

impl RowAccess for CsrMat {
    fn rows(&self) -> usize {
        CsrMat::rows(self)
    }

    fn cols(&self) -> usize {
        CsrMat::cols(self)
    }

    fn row_norms_sq_vec(&self) -> Vec<f32> {
        self.row_norms_sq()
    }

    fn dot_row(&self, i: usize, a: &[f32]) -> f32 {
        let (idx, vals) = self.row(i);
        sp_dot_dense(idx, vals, a)
    }

    fn axpy_row(&self, i: usize, scale: f32, a: &mut [f32]) {
        let (idx, vals) = self.row(i);
        sp_axpy_into_dense(scale, idx, vals, a)
    }

    fn residual_vec(&self, y: &[f32], a: &[f32]) -> Vec<f32> {
        let xa = self.spmv(a);
        y.iter().zip(&xa).map(|(&yi, &xi)| yi - xi).collect()
    }
}

/// Block-parallel SolveBak on dense columns. `opts.threads` sets the block
/// count; 1 reduces to [`crate::solver::solve_bak`] exactly.
pub fn solve_bak_par(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    bak_par_generic(x, y, opts)
}

/// Block-parallel SolveBak on CSC storage (O(nnz) per sweep per block).
pub fn solve_bak_par_csc(x: &CscMat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    bak_par_generic(x, y, opts)
}

fn bak_par_generic<C: ColAccess>(x: &C, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = (x.rows(), x.cols());
    assert_eq!(y.len(), obs, "y length must equal obs");
    let threads = opts.threads.max(1);
    let cninv = x.colnorms_inv_vec();
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let blocks = partition_ranges(vars, threads);
    let nb = blocks.len();

    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        // Phase 1 — concurrent inner sweeps: each block refreshes its own
        // residual copy per column (Algorithm 1 within the block) but sees
        // the other blocks' updates only at the sync below.
        let e_shared: &[f32] = &e;
        let mut results: Vec<(Vec<f32>, Vec<f32>)> = par_map_chunks(threads, nb, |b| {
            let blk = &blocks[b];
            let mut e_loc = e_shared.to_vec();
            let mut da = vec![0.0f32; blk.len()];
            // Column visit order within the block: cyclic by default;
            // Shuffled draws a fresh in-block permutation per sweep from
            // the (seed, block, sweep) stream — deterministic, like every
            // other randomized piece of this module.
            let mut order: Vec<usize> = blk.clone().collect();
            if opts.order == ColumnOrder::Shuffled {
                let mut rng =
                    Rng::seed(stream_seed(opts.seed, (sweep * nb + b) as u64));
                rng.shuffle(&mut order);
            }
            for &j in &order {
                let cn = cninv[j];
                if cn == 0.0 {
                    continue; // zero column
                }
                da[j - blk.start] = x.cd_step(j, &mut e_loc, cn);
            }
            (da, e_loc)
        });

        // Phase 2 — sync. Coefficients merge additively (disjoint column
        // ownership); the residual is rebuilt from the block locals:
        // e_b = e − X_b da_b, so e' = e − Σ_b X_b da_b = Σ_b e_b − (B−1)e,
        // an O(B·obs) row-parallel fold instead of re-touching the matrix.
        if nb == 1 {
            let (da, e_loc) = results.pop().expect("one block");
            for (k, &d) in da.iter().enumerate() {
                a[k] += d;
            }
            e = e_loc;
        } else {
            for (blk, (da, _)) in blocks.iter().zip(&results) {
                for (k, &d) in da.iter().enumerate() {
                    a[blk.start + k] += d;
                }
            }
            let coeff = (nb - 1) as f64;
            par_for_disjoint(threads, &mut e, |r0, window| {
                for (i, w) in window.iter_mut().enumerate() {
                    let r = r0 + i;
                    let mut acc = -coeff * (*w as f64);
                    for (_, e_loc) in &results {
                        acc += e_loc[r] as f64;
                    }
                    *w = acc as f32;
                }
            });
        }

        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(&e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, &a, &e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            // Guard for the cross-block staleness caveat: stalls AND
            // divergence (correlated columns split across blocks) both
            // stop here instead of burning sweeps.
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// Row-partitioned parallel randomized Kaczmarz (averaging sync) on the
/// dense layout.
pub fn solve_kaczmarz_par(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    kaczmarz_par_generic(x, y, opts)
}

/// Row-partitioned parallel randomized Kaczmarz on CSR storage.
pub fn solve_kaczmarz_par_csr(x: &CsrMat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    kaczmarz_par_generic(x, y, opts)
}

fn kaczmarz_par_generic<R: RowAccess>(x: &R, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = (x.rows(), x.cols());
    assert_eq!(y.len(), obs, "y length must equal obs");
    let threads = opts.threads.max(1);
    let row_norms_sq = x.row_norms_sq_vec();
    let total: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
    let y_norm_sq = blas1::sum_sq_f64(y);
    if total == 0.0 {
        // All-zero matrix: no projection moves the iterate (mirrors the
        // serial solvers' trivial-report path).
        let stop = if y_norm_sq == 0.0 { StopReason::Converged } else { StopReason::Stalled };
        return SolveReport {
            a: vec![0.0f32; vars],
            e: y.to_vec(),
            history: vec![y_norm_sq],
            y_norm_sq,
            sweeps: 0,
            stop,
        };
    }

    // Per-block sampling state: Strohmer-Vershynin norm-weighted CDF
    // restricted to the block's rows, plus the block's share of the total
    // row-norm mass (its averaging weight).
    struct Block {
        range: std::ops::Range<usize>,
        cdf: Vec<f64>,
        mass: f64,
    }
    let blocks: Vec<Block> = partition_ranges(obs, threads)
        .into_iter()
        .map(|range| {
            let mass: f64 =
                row_norms_sq[range.clone()].iter().map(|&v| v as f64).sum();
            let mut cdf = Vec::with_capacity(range.len());
            let mut acc = 0.0f64;
            for &v in &row_norms_sq[range.clone()] {
                acc += if mass > 0.0 { v as f64 / mass } else { 0.0 };
                cdf.push(acc);
            }
            Block { range, cdf, mass }
        })
        .collect();
    let nb = blocks.len();

    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        // Each block projects onto its own rows; the RNG stream is keyed
        // by (seed, block, sweep) — never by the OS worker — so the result
        // is deterministic per (seed, threads).
        let a_shared: &[f32] = &a;
        let iterates: Vec<Vec<f32>> = par_map_chunks(threads, nb, |b| {
            let blk = &blocks[b];
            let mut ab = a_shared.to_vec();
            if blk.mass == 0.0 {
                return ab; // all-zero rows; weight 0 below
            }
            let mut rng =
                Rng::seed(stream_seed(opts.seed, (sweep * nb + b) as u64));
            for _ in 0..blk.range.len() {
                let u = rng.uniform();
                let k = match blk.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                    Ok(k) => k,
                    Err(k) => k.min(blk.range.len() - 1),
                };
                let i = blk.range.start + k;
                let nrm = row_norms_sq[i];
                if nrm == 0.0 {
                    continue;
                }
                let ri = y[i] - x.dot_row(i, &ab);
                x.axpy_row(i, ri / nrm, &mut ab);
            }
            ab
        });

        // Averaging sync: mass-weighted mean of the block iterates (f64
        // accumulation, block order) — weights sum to 1 by construction.
        for (j, aj) in a.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for (blk, ab) in blocks.iter().zip(&iterates) {
                acc += (blk.mass / total) * ab[j] as f64;
            }
            *aj = acc as f32;
        }

        sweeps = sweep + 1;
        let e = x.residual_vec(y, &a);
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    let e = x.residual_vec(y, &a);
    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// Multi-RHS SolveBak with the RHS set chunked across `opts.threads`
/// workers: column norms are computed once and shared, and every chunk's
/// matrix walk serves all of its systems per sweep.
pub fn solve_bak_multi_par(x: &Mat, ys: &[Vec<f32>], opts: &SolveOptions) -> Vec<SolveReport> {
    bak_multi_par_generic(x, ys, opts)
}

/// Multi-RHS SolveBak on CSC storage: one O(nnz) traversal per sweep per
/// chunk serves every right-hand side in the chunk.
pub fn solve_bak_multi_par_csc(
    x: &CscMat,
    ys: &[Vec<f32>],
    opts: &SolveOptions,
) -> Vec<SolveReport> {
    bak_multi_par_generic(x, ys, opts)
}

fn bak_multi_par_generic<C: ColAccess>(
    x: &C,
    ys: &[Vec<f32>],
    opts: &SolveOptions,
) -> Vec<SolveReport> {
    let obs = x.rows();
    for y in ys {
        assert_eq!(y.len(), obs, "every RHS must have obs rows");
    }
    if ys.is_empty() {
        return Vec::new();
    }
    let threads = opts.threads.max(1);
    let cninv = x.colnorms_inv_vec(); // once, for every RHS on every worker
    let chunks = partition_ranges(ys.len(), threads);
    // Only the chunk holding the global first RHS reports to the probe
    // (one trajectory per solve, mirroring the serial multi-RHS solver).
    let no_probe = crate::obs::ProbeHandle::none();
    let per_chunk: Vec<Vec<SolveReport>> = par_map_chunks(threads, chunks.len(), |c| {
        let probe = if c == 0 { &opts.probe } else { &no_probe };
        bak_multi_chunk(x, &cninv, &ys[chunks[c].clone()], opts, probe)
    });
    per_chunk.into_iter().flatten().collect()
}

/// Serial multi-RHS walk for one chunk (mirrors
/// [`crate::solver::solve_bak_multi`], with the column norms hoisted out).
fn bak_multi_chunk<C: ColAccess>(
    x: &C,
    cninv: &[f32],
    ys: &[Vec<f32>],
    opts: &SolveOptions,
    probe: &crate::obs::ProbeHandle,
) -> Vec<SolveReport> {
    let vars = x.cols();
    let nrhs = ys.len();
    let mut a: Vec<Vec<f32>> = vec![vec![0.0f32; vars]; nrhs];
    let mut e: Vec<Vec<f32>> = ys.to_vec();
    let y_norm_sq: Vec<f64> = ys.iter().map(|y| blas1::sum_sq_f64(y)).collect();
    let mut history: Vec<Vec<f64>> = vec![Vec::new(); nrhs];
    let mut done: Vec<Option<StopReason>> = vec![None; nrhs];
    let mut prev_r2 = vec![f64::INFINITY; nrhs];
    let mut sweeps_done = vec![0usize; nrhs];
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        if done.iter().all(Option::is_some) {
            break;
        }
        for j in 0..vars {
            let cn = cninv[j];
            if cn == 0.0 {
                continue;
            }
            for r in 0..nrhs {
                if done[r].is_some() {
                    continue;
                }
                let da = x.cd_step(j, &mut e[r], cn);
                a[r][j] += da;
            }
        }
        for r in 0..nrhs {
            if done[r].is_some() {
                continue;
            }
            sweeps_done[r] = sweep + 1;
            let r2 = blas1::sum_sq_f64(&e[r]);
            history[r].push(r2);
            if r == 0 {
                probe.observe(sweeps_done[r], r2, t0);
                if r2.is_finite() {
                    probe.observe_state(sweeps_done[r], &a[r], &e[r], r2);
                }
            }
            if !r2.is_finite() {
                done[r] = Some(StopReason::Breakdown);
            } else if opts.tol > 0.0 && r2 <= opts.tol * opts.tol * y_norm_sq[r] {
                done[r] = Some(StopReason::Converged);
            } else if r2 >= prev_r2[r] * (1.0 - 1e-9) && sweep > 0 {
                done[r] = Some(StopReason::Stalled);
            }
            prev_r2[r] = r2;
        }
        if opts.cancel.is_cancelled() {
            for d in done.iter_mut() {
                if d.is_none() {
                    *d = Some(StopReason::Cancelled);
                }
            }
            break;
        }
    }

    (0..nrhs)
        .map(|r| SolveReport {
            a: std::mem::take(&mut a[r]),
            e: std::mem::take(&mut e[r]),
            history: std::mem::take(&mut history[r]),
            y_norm_sq: y_norm_sq[r],
            sweeps: sweeps_done[r],
            stop: done[r].unwrap_or(StopReason::MaxSweeps),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{solve_bak, solve_bak_multi, solve_kaczmarz};
    use crate::util::stats::rel_l2;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    fn planted_sparse(
        seed: u64,
        obs: usize,
        vars: usize,
        density: f64,
    ) -> (CscMat, Vec<f32>, Vec<f32>) {
        let w = crate::bench::workload::SparseWorkload::uniform(
            crate::bench::workload::WorkloadSpec::new(obs, vars, seed),
            density,
        );
        (w.x, w.y, w.a_true)
    }

    #[test]
    fn bak_par_single_thread_matches_serial_exactly() {
        let (x, y, _) = planted(900, 120, 24);
        let mut o = SolveOptions::default();
        o.max_sweeps = 4;
        o.tol = 0.0;
        o.threads = 1;
        let rp = solve_bak_par(&x, &y, &o);
        let rs = solve_bak(&x, &y, &o);
        assert_eq!(rp.a, rs.a, "threads=1 must be Algorithm 1 bit-for-bit");
        assert_eq!(rp.e, rs.e);
    }

    #[test]
    fn bak_par_converges_and_is_deterministic_across_thread_counts() {
        let (x, y, a_true) = planted(901, 600, 48);
        for threads in [1usize, 2, 8] {
            let mut o = SolveOptions::accurate();
            o.threads = threads;
            let r1 = solve_bak_par(&x, &y, &o);
            let r2 = solve_bak_par(&x, &y, &o);
            assert_eq!(r1.a, r2.a, "threads={threads} must be deterministic");
            assert!(
                r1.rel_residual() < 1e-4,
                "threads={threads} rel={}",
                r1.rel_residual()
            );
            assert!(
                rel_l2(&r1.a, &a_true) < 1e-3,
                "threads={threads} err={}",
                rel_l2(&r1.a, &a_true)
            );
        }
    }

    #[test]
    fn bak_par_exit_invariant() {
        let (x, y, _) = planted(902, 200, 32);
        let mut o = SolveOptions::default();
        o.threads = 4;
        let rep = solve_bak_par(&x, &y, &o);
        let fresh = crate::linalg::residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-3, "{f} vs {g}");
        }
    }

    #[test]
    fn bak_par_csc_matches_dense_blocks() {
        let (x, y, _) = planted_sparse(903, 150, 20, 0.2);
        let dense = x.to_dense();
        let mut o = SolveOptions::default();
        o.max_sweeps = 4;
        o.tol = 0.0;
        o.threads = 3;
        let rs = solve_bak_par_csc(&x, &y, &o);
        let rd = solve_bak_par(&dense, &y, &o);
        assert_eq!(rs.sweeps, rd.sweeps);
        for (s, d) in rs.a.iter().zip(&rd.a) {
            assert!((s - d).abs() < 1e-3, "{s} vs {d}");
        }
    }

    #[test]
    fn bak_par_zero_column_ignored() {
        let mut rng = Rng::seed(904);
        let mut x = Mat::randn(&mut rng, 60, 9);
        x.col_mut(4).fill(0.0);
        let y: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.threads = 3;
        let rep = solve_bak_par(&x, &y, &o);
        assert_eq!(rep.a[4], 0.0);
        assert!(rep.a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bak_par_shuffled_order_converges_and_is_deterministic() {
        let (x, y, a_true) = planted(915, 500, 40);
        let mut o = SolveOptions::accurate();
        o.order = ColumnOrder::Shuffled;
        o.threads = 3;
        let r1 = solve_bak_par(&x, &y, &o);
        let r2 = solve_bak_par(&x, &y, &o);
        assert_eq!(r1.a, r2.a, "shuffled order still deterministic per seed");
        assert!(r1.rel_residual() < 1e-4, "rel={}", r1.rel_residual());
        assert!(rel_l2(&r1.a, &a_true) < 1e-3);
        // A different seed draws different permutations.
        let mut o2 = o.clone();
        o2.seed = o.seed ^ 0xdead;
        let r3 = solve_bak_par(&x, &y, &o2);
        assert_ne!(r1.a, r3.a, "permutation stream depends on the seed");
    }

    #[test]
    fn kaczmarz_par_converges_and_is_deterministic() {
        // 240x20: even at 8 blocks every 30-row block is overdetermined,
        // so each block's projections pull hard toward the unique solution
        // and the averaging sync converges for every thread count.
        let (x, y, a_true) = planted(905, 240, 20);
        for threads in [1usize, 2, 8] {
            let mut o = SolveOptions::default();
            o.max_sweeps = 2000;
            o.tol = 1e-4;
            o.threads = threads;
            let r1 = solve_kaczmarz_par(&x, &y, &o);
            let r2 = solve_kaczmarz_par(&x, &y, &o);
            assert_eq!(r1.a, r2.a, "threads={threads} must be deterministic");
            assert!(
                r1.rel_residual() < 1e-3,
                "threads={threads} rel={}",
                r1.rel_residual()
            );
            assert!(rel_l2(&r1.a, &a_true) < 0.05, "threads={threads}");
        }
    }

    #[test]
    fn kaczmarz_par_matches_serial_quality() {
        let (x, y, _) = planted(906, 160, 20);
        let mut o = SolveOptions::default();
        o.max_sweeps = 400;
        o.tol = 1e-5;
        let serial = solve_kaczmarz(&x, &y, &o);
        o.threads = 4;
        let par = solve_kaczmarz_par(&x, &y, &o);
        // Different sampling sequences, same target: both land within the
        // tolerance regime of the serial solution.
        assert!(par.rel_residual() < serial.rel_residual().max(1e-4) * 10.0 + 1e-4);
        assert!(rel_l2(&par.a, &serial.a) < 0.05);
    }

    #[test]
    fn kaczmarz_par_csr_matches_dense_variant_exactly() {
        let (x, y, _) = planted_sparse(907, 80, 16, 0.3);
        let csr = x.to_csr();
        let dense = x.to_dense();
        let mut o = SolveOptions::default();
        o.max_sweeps = 5;
        o.tol = 0.0;
        o.threads = 2;
        let rs = solve_kaczmarz_par_csr(&csr, &y, &o);
        let rd = solve_kaczmarz_par(&dense, &y, &o);
        assert_eq!(rs.sweeps, rd.sweeps);
        for (s, d) in rs.a.iter().zip(&rd.a) {
            assert!((s - d).abs() < 1e-3, "{s} vs {d}");
        }
    }

    #[test]
    fn kaczmarz_par_zero_matrix_trivial() {
        let x = Mat::zeros(6, 3);
        let mut o = SolveOptions::default();
        o.threads = 4;
        let rep = solve_kaczmarz_par(&x, &[1.0; 6], &o);
        assert_eq!(rep.a, vec![0.0; 3]);
        assert_eq!(rep.stop, StopReason::Stalled);
        let rep = solve_kaczmarz_par(&x, &[0.0; 6], &o);
        assert_eq!(rep.stop, StopReason::Converged);
    }

    #[test]
    fn multi_par_matches_serial_multi() {
        let (x, _, _) = planted(908, 150, 25);
        let mut rng = Rng::seed(909);
        let ys: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                let a: Vec<f32> = (0..25).map(|_| rng.normal_f32()).collect();
                x.matvec(&a)
            })
            .collect();
        let mut o = SolveOptions::default();
        o.max_sweeps = 50;
        o.tol = 1e-6;
        let serial = solve_bak_multi(&x, &ys, &o);
        o.threads = 3;
        let par = solve_bak_multi_par(&x, &ys, &o);
        assert_eq!(par.len(), serial.len());
        for (p, s) in par.iter().zip(&serial) {
            assert!(rel_l2(&p.a, &s.a) < 1e-4, "{}", rel_l2(&p.a, &s.a));
            assert_eq!(p.stop, s.stop);
        }
    }

    #[test]
    fn multi_par_csc_solves_every_rhs() {
        let (x, _, _) = planted_sparse(910, 200, 15, 0.2);
        let mut rng = Rng::seed(911);
        let ys: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let a: Vec<f32> = (0..15).map(|_| rng.normal_f32()).collect();
                x.matvec(&a)
            })
            .collect();
        let mut o = SolveOptions::accurate();
        o.threads = 2;
        let reps = solve_bak_multi_par_csc(&x, &ys, &o);
        assert_eq!(reps.len(), 4);
        for rep in &reps {
            assert!(rep.converged(), "rel={}", rep.rel_residual());
        }
    }

    #[test]
    fn multi_par_empty_rhs_set() {
        let (x, _, _) = planted(912, 20, 4);
        assert!(solve_bak_multi_par(&x, &[], &SolveOptions::default()).is_empty());
    }

    #[test]
    fn bak_par_more_threads_than_columns() {
        let (x, y, a_true) = planted(913, 300, 3);
        let mut o = SolveOptions::accurate();
        o.threads = 16; // clamped to vars blocks internally
        let rep = solve_bak_par(&x, &y, &o);
        assert!(rep.rel_residual() < 1e-4, "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn bak_par_history_guard_stops_on_non_improvement() {
        // Correlated columns split across blocks: the §6-style overshoot
        // must be caught by the guard, not loop to max_sweeps.
        let mut rng = Rng::seed(914);
        let obs = 80;
        let vars = 32;
        let base: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();
        let x = Mat::from_fn(obs, vars, |i, _| base[i] + 0.02 * rng.normal_f32());
        let y: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.threads = 8;
        o.max_sweeps = 100_000;
        o.tol = 1e-30; // unreachable
        let rep = solve_bak_par(&x, &y, &o);
        assert!(rep.sweeps < 100_000, "guard must fire");
    }
}
