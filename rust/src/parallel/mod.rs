//! The parallel execution layer: a std-only worker pool plus the
//! block-parallel solver variants built on it.
//!
//! Three pieces, bottom-up:
//!
//! * [`queue`] — the bounded MPMC injector (moved here from the
//!   coordinator, which re-exports it): blocking backpressure, graceful
//!   close-and-drain.
//! * [`pool`] — [`Executor`] (long-lived named workers, panic isolation
//!   per job, [`PoolStats`] gauges) and the scoped fork-join helpers
//!   ([`par_map_chunks`] chunk-stealing map, [`par_for_disjoint`] split
//!   mutation, [`partition_ranges`] deterministic block structure,
//!   [`stream_seed`] per-work-item RNG streams).
//! * [`solvers`] — `bak_par` / `kaczmarz_par` / `bak_multi_par` in dense
//!   and sparse storage, sharing one block scheduler. Addressable through
//!   the [`crate::api`] registry as `SolverKind::{BakPar, KaczmarzPar}`.
//!
//! Thread-count configuration flows top-down: the CLI's `--threads`, the
//! TCP protocol's `"threads"` field, and the `PALLAS_THREADS` environment
//! variable (read by [`default_threads`]) all end up in
//! [`crate::solver::SolveOptions::threads`] for solver-level parallelism,
//! and in [`crate::coordinator::CoordinatorConfig::workers`] for
//! job-level parallelism.

pub mod pool;
pub mod queue;
pub mod solvers;

pub use pool::{
    par_for_disjoint, par_map_chunks, partition_ranges, stream_seed, Executor, PoolStats,
};
pub use solvers::{
    solve_bak_multi_par, solve_bak_multi_par_csc, solve_bak_par, solve_bak_par_csc,
    solve_kaczmarz_par, solve_kaczmarz_par_csr,
};

/// The `PALLAS_THREADS` environment override, when set to a positive
/// integer (malformed or non-positive values read as unset).
pub fn env_threads() -> Option<usize> {
    std::env::var("PALLAS_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// The configured default worker/thread count: [`env_threads`] when set,
/// otherwise the machine's available parallelism capped at the cgroup v2
/// CPU quota (1 when neither can be determined).
///
/// Inside a container, `available_parallelism` often reports the host's
/// core count while the cgroup caps the process at a fraction of it;
/// sizing the pool to the host count oversubscribes the quota and every
/// sweep pays the throttle. The quota is read from
/// `/sys/fs/cgroup/cpu.max` (cgroup v2: `"<quota> <period>"` in
/// microseconds, or `"max <period>"` for unlimited) and rounded up, so a
/// `1.5`-CPU container gets 2 threads, not 16.
pub fn default_threads() -> usize {
    if let Some(n) = env_threads() {
        return n;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let quota = std::fs::read_to_string("/sys/fs/cgroup/cpu.max")
        .ok()
        .and_then(|s| parse_cpu_max(&s));
    match quota {
        Some(q) => hw.min(q).max(1),
        None => hw,
    }
}

/// Parse a cgroup v2 `cpu.max` file: `"<quota> <period>"` in
/// microseconds, where quota is `max` for unlimited. Returns the CPU
/// count the quota allows, rounded up; `None` means no usable limit
/// (unlimited, malformed, or a zero period).
fn parse_cpu_max(s: &str) -> Option<usize> {
    let mut parts = s.split_whitespace();
    let quota = parts.next()?;
    let period = parts.next()?.parse::<u64>().ok().filter(|&p| p > 0)?;
    if quota == "max" {
        return None;
    }
    let quota = quota.parse::<u64>().ok().filter(|&q| q > 0)?;
    Some(quota.div_ceil(period) as usize)
}

#[cfg(test)]
mod tests {
    use super::parse_cpu_max;

    #[test]
    fn default_threads_is_positive() {
        // Whatever the environment says, the answer is a usable count.
        assert!(super::default_threads() >= 1);
    }

    #[test]
    fn cpu_max_quota_rounds_up() {
        // 1.5 CPUs of quota must still run 2 threads, not 1.
        assert_eq!(parse_cpu_max("150000 100000\n"), Some(2));
        assert_eq!(parse_cpu_max("100000 100000"), Some(1));
        assert_eq!(parse_cpu_max("400000 100000"), Some(4));
        // Sub-CPU quotas clamp to one full thread at the call site but
        // the parser itself reports the ceiling: 0.2 CPU -> 1.
        assert_eq!(parse_cpu_max("20000 100000"), Some(1));
    }

    #[test]
    fn cpu_max_unlimited_or_malformed_is_none() {
        assert_eq!(parse_cpu_max("max 100000\n"), None);
        assert_eq!(parse_cpu_max(""), None);
        assert_eq!(parse_cpu_max("100000"), None);
        assert_eq!(parse_cpu_max("banana 100000"), None);
        assert_eq!(parse_cpu_max("100000 0"), None);
        assert_eq!(parse_cpu_max("0 100000"), None);
    }
}
