//! The parallel execution layer: a std-only worker pool plus the
//! block-parallel solver variants built on it.
//!
//! Three pieces, bottom-up:
//!
//! * [`queue`] — the bounded MPMC injector (moved here from the
//!   coordinator, which re-exports it): blocking backpressure, graceful
//!   close-and-drain.
//! * [`pool`] — [`Executor`] (long-lived named workers, panic isolation
//!   per job, [`PoolStats`] gauges) and the scoped fork-join helpers
//!   ([`par_map_chunks`] chunk-stealing map, [`par_for_disjoint`] split
//!   mutation, [`partition_ranges`] deterministic block structure,
//!   [`stream_seed`] per-work-item RNG streams).
//! * [`solvers`] — `bak_par` / `kaczmarz_par` / `bak_multi_par` in dense
//!   and sparse storage, sharing one block scheduler. Addressable through
//!   the [`crate::api`] registry as `SolverKind::{BakPar, KaczmarzPar}`.
//!
//! Thread-count configuration flows top-down: the CLI's `--threads`, the
//! TCP protocol's `"threads"` field, and the `PALLAS_THREADS` environment
//! variable (read by [`default_threads`]) all end up in
//! [`crate::solver::SolveOptions::threads`] for solver-level parallelism,
//! and in [`crate::coordinator::CoordinatorConfig::workers`] for
//! job-level parallelism.

pub mod pool;
pub mod queue;
pub mod solvers;

pub use pool::{
    par_for_disjoint, par_map_chunks, partition_ranges, stream_seed, Executor, PoolStats,
};
pub use solvers::{
    solve_bak_multi_par, solve_bak_multi_par_csc, solve_bak_par, solve_bak_par_csc,
    solve_kaczmarz_par, solve_kaczmarz_par_csr,
};

/// The `PALLAS_THREADS` environment override, when set to a positive
/// integer (malformed or non-positive values read as unset).
pub fn env_threads() -> Option<usize> {
    std::env::var("PALLAS_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// The configured default worker/thread count: [`env_threads`] when set,
/// otherwise the machine's available parallelism (1 when that cannot be
/// determined).
pub fn default_threads() -> usize {
    env_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    #[test]
    fn default_threads_is_positive() {
        // Whatever the environment says, the answer is a usable count.
        assert!(super::default_threads() >= 1);
    }
}
