//! The worker pool: a long-lived [`Executor`] for job streams (the
//! coordinator's execution backend) and scoped data-parallel helpers for
//! the block-parallel solvers — all std-only.
//!
//! Two execution shapes live here:
//!
//! * [`Executor`] — N named workers pulling typed jobs from a bounded
//!   injector ([`super::queue::BoundedQueue`]). Jobs are panic-isolated
//!   (`catch_unwind` per job: a panicking job is counted and dropped, the
//!   worker survives), shutdown is graceful (pending jobs drain before the
//!   workers exit), and [`PoolStats`] exposes busy/inflight gauges plus
//!   per-worker job counts for the metrics layer.
//! * [`par_map_chunks`] / [`par_for_disjoint`] — scoped fork-join over a
//!   chunked work queue: workers *steal* the next chunk index from a
//!   shared atomic cursor, so uneven chunk costs balance automatically,
//!   while every chunk writes its own output slot — results are
//!   deterministic no matter which worker ran which chunk.
//!
//! Determinism contract: anything randomized keys its RNG off the
//! *work item* (block/chunk index via [`stream_seed`]), never off the OS
//! worker that happened to execute it. The solvers in [`super::solvers`]
//! rely on this to produce bit-identical results for a fixed
//! `(seed, threads)` across runs and schedulers.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::log::{emit, Level};
use crate::util::rng::SplitMix64;

use super::queue::BoundedQueue;

/// Observable state of a running [`Executor`]: gauges move as jobs flow,
/// counters only grow. All relaxed atomics — metrics, not synchronization.
pub struct PoolStats {
    /// Number of worker threads in the pool.
    workers: usize,
    /// Gauge: workers currently executing a job.
    pub workers_busy: AtomicU64,
    /// Gauge: jobs submitted but not yet finished (queued + running).
    pub jobs_inflight: AtomicU64,
    /// Jobs that ran to completion.
    pub jobs_completed: AtomicU64,
    /// Jobs whose handler panicked (isolated; the worker survived).
    pub jobs_panicked: AtomicU64,
    /// Jobs executed per worker (load-balance observability).
    per_worker: Vec<AtomicU64>,
}

impl PoolStats {
    fn new(workers: usize) -> Self {
        Self {
            workers,
            workers_busy: AtomicU64::new(0),
            jobs_inflight: AtomicU64::new(0),
            jobs_completed: AtomicU64::new(0),
            jobs_panicked: AtomicU64::new(0),
            per_worker: (0..workers).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Snapshot of per-worker executed-job counts.
    pub fn worker_jobs(&self) -> Vec<u64> {
        self.per_worker.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// A fixed pool of named workers executing a stream of typed jobs through
/// one shared handler. See the module docs for the isolation/shutdown
/// contract.
pub struct Executor<T: Send + 'static> {
    injector: Arc<BoundedQueue<T>>,
    stats: Arc<PoolStats>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> Executor<T> {
    /// Spawn `threads` workers named `{name}-{i}` over a bounded injector
    /// of the given capacity. `handler(worker_index, job)` runs every job;
    /// a panic inside it is caught, counted, and logged — the worker keeps
    /// serving.
    pub fn start<F>(name: &str, threads: usize, capacity: usize, handler: F) -> Self
    where
        F: Fn(usize, T) + Send + Sync + 'static,
    {
        let threads = threads.max(1);
        let injector: Arc<BoundedQueue<T>> = Arc::new(BoundedQueue::new(capacity.max(1)));
        let stats = Arc::new(PoolStats::new(threads));
        let handler = Arc::new(handler);
        let workers = (0..threads)
            .map(|i| {
                let injector = injector.clone();
                let stats = stats.clone();
                let handler = handler.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Some(job) = injector.pop() {
                            stats.workers_busy.fetch_add(1, Ordering::Relaxed);
                            let outcome = catch_unwind(AssertUnwindSafe(|| handler(i, job)));
                            stats.workers_busy.fetch_sub(1, Ordering::Relaxed);
                            stats.jobs_inflight.fetch_sub(1, Ordering::Relaxed);
                            stats.per_worker[i].fetch_add(1, Ordering::Relaxed);
                            match outcome {
                                Ok(()) => {
                                    stats.jobs_completed.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(_) => {
                                    stats.jobs_panicked.fetch_add(1, Ordering::Relaxed);
                                    emit(
                                        Level::Error,
                                        "parallel",
                                        format_args!(
                                            "job panicked in worker {i}; worker continues"
                                        ),
                                    );
                                }
                            }
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { injector, stats, workers }
    }

    /// Blocking submit (backpressure while the injector is full).
    /// Err(`Closed`) once the pool is shut down — the job is dropped,
    /// matching [`BoundedQueue::push`] semantics.
    pub fn submit(&self, job: T) -> Result<(), super::queue::Closed> {
        self.stats.jobs_inflight.fetch_add(1, Ordering::Relaxed);
        self.injector.push(job).map_err(|c| {
            self.stats.jobs_inflight.fetch_sub(1, Ordering::Relaxed);
            c
        })
    }

    /// Pool statistics (shared; stays valid after shutdown).
    pub fn stats(&self) -> Arc<PoolStats> {
        self.stats.clone()
    }

    /// Current injector depth (racy; metrics only).
    pub fn queued(&self) -> usize {
        self.injector.len()
    }

    /// Graceful shutdown: close intake, let workers drain every pending
    /// job, join them.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.injector.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<T: Send + 'static> Drop for Executor<T> {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Derive the RNG seed for one work stream (block/chunk `stream`) from a
/// base seed. SplitMix64 over the combined words: well-mixed, and stable
/// across runs — the seed depends on the *work item*, not the worker.
pub fn stream_seed(base: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(base ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
    sm.next_u64()
}

/// Fork-join map over `n` indexed chunks on up to `threads` scoped
/// workers. Workers steal the next chunk from a shared atomic cursor
/// (self-scheduling: uneven chunks balance), each chunk's result lands in
/// its own slot, and the returned Vec is in chunk order — deterministic
/// regardless of scheduling.
pub fn par_map_chunks<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let slots: Vec<std::sync::OnceLock<T>> =
        (0..n).map(|_| std::sync::OnceLock::new()).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = slots[i].set(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|c| c.into_inner().expect("every chunk computed"))
        .collect()
}

/// Fork-join over disjoint mutable chunks of `data`: splits into `pieces`
/// near-equal contiguous chunks and runs `f(start_index, chunk)` on up to
/// `pieces` scoped workers. Static assignment (chunk i -> spawned task i):
/// the chunks are the parallelism grain, so stealing buys nothing here.
pub fn par_for_disjoint<T: Send, F>(threads: usize, data: &mut [T], f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let threads = threads.clamp(1, data.len().max(1));
    if threads <= 1 || data.len() <= 1 {
        f(0, data);
        return;
    }
    let per = data.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in data.chunks_mut(per).enumerate() {
            let f = &f;
            s.spawn(move || f(t * per, chunk));
        }
    });
}

/// Balanced contiguous partition of `0..n` into at most `pieces` non-empty
/// ranges. The partition depends only on `(n, pieces)` — solvers key their
/// block structure (and block RNG streams) off it for determinism.
pub fn partition_ranges(n: usize, pieces: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let pieces = pieces.max(1).min(n);
    let base = n / pieces;
    let extra = n % pieces;
    let mut out = Vec::with_capacity(pieces);
    let mut start = 0;
    for p in 0..pieces {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn executor_runs_all_jobs() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        let pool = Executor::start("t", 4, 16, move |_w, v: u64| {
            h2.fetch_add(v, Ordering::Relaxed);
        });
        for v in 1..=10u64 {
            pool.submit(v).unwrap();
        }
        let stats = pool.stats();
        pool.shutdown();
        assert_eq!(hits.load(Ordering::Relaxed), 55);
        assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 10);
        assert_eq!(stats.worker_jobs().iter().sum::<u64>(), 10);
        assert_eq!(stats.jobs_inflight.load(Ordering::Relaxed), 0);
        assert_eq!(stats.workers_busy.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn executor_isolates_panicking_jobs() {
        let ok = Arc::new(AtomicU64::new(0));
        let ok2 = ok.clone();
        let pool = Executor::start("t", 2, 8, move |_w, v: i32| {
            if v < 0 {
                panic!("boom");
            }
            ok2.fetch_add(1, Ordering::Relaxed);
        });
        pool.submit(1).unwrap();
        pool.submit(-1).unwrap();
        pool.submit(2).unwrap();
        pool.submit(3).unwrap();
        let stats = pool.stats();
        pool.shutdown();
        assert_eq!(ok.load(Ordering::Relaxed), 3);
        assert_eq!(stats.jobs_panicked.load(Ordering::Relaxed), 1);
        assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 3);
        assert_eq!(stats.jobs_inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shutdown_drains_pending_jobs() {
        let done = Arc::new(AtomicU64::new(0));
        let d2 = done.clone();
        let pool = Executor::start("t", 1, 32, move |_w, _v: u32| {
            std::thread::sleep(Duration::from_millis(2));
            d2.fetch_add(1, Ordering::Relaxed);
        });
        for v in 0..10 {
            pool.submit(v).unwrap();
        }
        // Immediate shutdown: intake closes, but queued jobs still run.
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn par_map_chunks_ordered_and_complete() {
        for threads in [1usize, 2, 3, 8] {
            let out = par_map_chunks(threads, 17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(par_map_chunks(4, 0, |i| i).is_empty());
    }

    #[test]
    fn par_for_disjoint_covers_every_slot() {
        let mut v = vec![0u32; 23];
        par_for_disjoint(4, &mut v, |start, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = (start + k) as u32 + 1;
            }
        });
        assert_eq!(v, (1..=23).collect::<Vec<u32>>());
    }

    #[test]
    fn partition_ranges_balanced_cover() {
        for (n, p) in [(10usize, 3usize), (7, 7), (7, 20), (1, 4), (64, 8)] {
            let parts = partition_ranges(n, p);
            assert!(parts.len() <= p.max(1));
            assert_eq!(parts.first().map(|r| r.start), Some(0));
            assert_eq!(parts.last().map(|r| r.end), Some(n));
            let total: usize = parts.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            let lens: Vec<usize> = parts.iter().map(|r| r.len()).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1, "balanced: {lens:?}");
            for w in parts.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
        }
        assert!(partition_ranges(0, 4).is_empty());
    }

    #[test]
    fn stream_seed_distinct_and_stable() {
        let a = stream_seed(42, 0);
        let b = stream_seed(42, 1);
        let c = stream_seed(43, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, stream_seed(42, 0));
    }
}
