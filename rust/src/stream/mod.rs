//! Out-of-core streaming: solve systems whose X never fits in RAM.
//!
//! The paper's structural claim — each iteration "utilizes only one
//! dimension of the given input matrix X" — means the solvers never need
//! the whole operand resident. This module makes that real:
//!
//! * [`format`] — the `.sbck` on-disk tiled store: a 32-byte header
//!   (magic `SBCK`, format version byte, rows/cols/chunk_cols) followed by
//!   the f32-LE column-major payload in chunks of whole columns, written
//!   from dense ([`write_chunked_dense`]), sparse ([`write_chunked_csc`]),
//!   or generated chunk-at-a-time ([`write_chunked_with`]) without ever
//!   materialising the matrix. [`StreamedMatrix`] is the typed handle;
//!   [`ChunkSource`] abstracts the reader.
//! * [`prefetch`] — the double-buffered pipeline: a reader thread fills a
//!   budget-bounded pool of chunk buffers (backpressure via
//!   [`crate::parallel::BoundedQueue`]) while the solver consumes the
//!   previous chunk. Peak resident payload ≤ pool budget; I/O counters in
//!   [`StreamStatsSnapshot`].
//! * [`solve`] — [`solve_bak_stream`] / [`solve_kaczmarz_stream`] /
//!   [`solve_bak_multi_stream`]: the existing per-column/per-row inner
//!   steps over streamed chunks, **bit-identical** to the in-memory path
//!   for the same seed (asserted with `assert_eq!` in the tests).
//!
//! Upstack: [`crate::api::MatrixRef::Streamed`] carries a
//! `&StreamedMatrix` through [`crate::api::Problem`], backends advertise
//! `supports_streaming` in their [`crate::api::Capabilities`], the
//! coordinator accepts `{"x_path": "..."}` requests and exports
//! `stream_*` metrics, and the CLI adds `convert` plus
//! `solve --x-file --mem-budget`.

pub mod format;
pub mod prefetch;
pub mod solve;

pub use format::{
    default_chunk_cols, read_vec_f32, temp_chunk_path, write_chunked_csc, write_chunked_dense,
    write_chunked_with, write_vec_f32, ChunkSource, FileChunkSource, StreamedMatrix,
    DEFAULT_MEM_BUDGET, FORMAT_VERSION, HEADER_LEN, MAGIC,
};
pub use prefetch::{Chunk, ChunkStream, StreamStats, StreamStatsSnapshot};
pub use solve::{
    solve_bak_multi_stream, solve_bak_stream, solve_kaczmarz_stream, StreamMultiReport,
    StreamReport,
};
