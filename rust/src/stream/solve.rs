//! Streaming solvers: the in-memory inner steps run over prefetched
//! chunks, bit-identical to the RAM path.
//!
//! Why bit-identical is achievable (and tested with `assert_eq!`, not a
//! tolerance):
//!
//! * **BAK / multi-RHS BAK** consume whole columns in cyclic order. A
//!   chunk-resident column is the same contiguous `&[f32]` the in-memory
//!   solver passes to [`blas1::cd_step`] / [`blas1::dot`] /
//!   [`blas1::axpy`], and the chunk layout never reorders columns, so
//!   every f32 operation replays in the same order with the same
//!   operands — for ANY chunk width.
//! * **Kaczmarz** samples rows. The RNG draws are hoisted: all `obs` row
//!   indices for a sweep are drawn up front (same `uniform()` sequence as
//!   the interleaved in-memory loop, which never touches the RNG between
//!   draws), the sampled rows are gathered in sequential chunk passes, and
//!   the projections replay in draw order. [`blas1::dot_strided`] /
//!   [`blas1::axpy_strided`] have stride-independent lane structure, so a
//!   stride-1 replay over a gathered row buffer is bitwise equal to the
//!   stride-`obs` in-memory call. Per-sweep residuals accumulate column-
//!   major with the same per-element `mul_add` order as
//!   [`crate::linalg::blas2::gemv`].
//!
//! Memory: chunk buffers are bounded by the [`StreamedMatrix::mem_budget`]
//! buffer pool ([`ChunkStream`]); the Kaczmarz row-gather buffer is capped
//! at half the budget by splitting each sweep's draws into batches (extra
//! sequential passes, never extra memory).

use crate::api::SolverError;
use crate::linalg::blas1;
use crate::solver::{ColumnOrder, SolveOptions, SolveReport, StopReason};
use crate::util::rng::Rng;

use super::format::StreamedMatrix;
use super::prefetch::{Chunk, ChunkStream, StreamStatsSnapshot};

/// Outcome of a single-RHS streaming solve.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub report: SolveReport,
    pub stats: StreamStatsSnapshot,
}

/// Outcome of a multi-RHS streaming solve (one report per RHS).
#[derive(Clone, Debug)]
pub struct StreamMultiReport {
    pub reports: Vec<SolveReport>,
    pub stats: StreamStatsSnapshot,
}

fn reader_err(stream: &ChunkStream) -> SolverError {
    match stream.take_error() {
        Some(e) => {
            // A failed chunk CRC travels as a CorruptChunk payload inside
            // the io::Error; surface it as the typed wire-visible variant
            // instead of an opaque backend failure.
            if let Some(c) = e.get_ref().and_then(|i| i.downcast_ref::<super::format::CorruptChunk>())
            {
                return SolverError::CorruptData {
                    chunk: c.chunk,
                    expected: c.expected,
                    actual: c.actual,
                };
            }
            SolverError::Backend {
                backend: "stream".into(),
                reason: format!("chunk read failed: {e}"),
            }
        }
        None => SolverError::Backend {
            backend: "stream".into(),
            reason: "chunk reader terminated".into(),
        },
    }
}

fn next_or_err(stream: &ChunkStream) -> Result<Chunk, SolverError> {
    stream.next().ok_or_else(|| reader_err(stream))
}

/// One full pass over the matrix: every chunk in order through `f`.
fn pass(
    stream: &ChunkStream,
    mut f: impl FnMut(usize, usize, &[f32]),
) -> Result<(), SolverError> {
    for _ in 0..stream.num_chunks() {
        let ch = next_or_err(stream)?;
        f(ch.start_col, ch.width, &ch.data);
        stream.recycle(ch.data);
    }
    Ok(())
}

fn start_stream(x: &StreamedMatrix) -> Result<ChunkStream, SolverError> {
    ChunkStream::start(x).map_err(|e| SolverError::Backend {
        backend: "stream".into(),
        reason: format!("open {}: {e}", x.path().display()),
    })
}

fn validate(x: &StreamedMatrix, y: &[f32], opts: &SolveOptions) -> Result<(), SolverError> {
    let (rows, cols) = x.shape();
    if rows == 0 || cols == 0 {
        return Err(SolverError::Shape(format!("empty streamed matrix {rows}x{cols}")));
    }
    if y.len() != rows {
        return Err(SolverError::Shape(format!("y has {} rows, x has {rows}", y.len())));
    }
    if opts.order == ColumnOrder::Shuffled {
        return Err(SolverError::InvalidInput(
            "streamed solvers require ColumnOrder::Cyclic (chunks are read sequentially)".into(),
        ));
    }
    Ok(())
}

/// `1/<x_j,x_j>` via one streamed pass — bit-identical to
/// [`crate::solver::colnorms_inv`] (same `nrm2_sq` on the same slices,
/// same zero-column mapping).
fn streamed_colnorms_inv(stream: &ChunkStream, cols: usize) -> Result<Vec<f32>, SolverError> {
    let rows = stream.rows();
    let mut cninv = vec![0.0f32; cols];
    pass(stream, |j0, width, data| {
        for l in 0..width {
            let n = blas1::nrm2_sq(&data[l * rows..(l + 1) * rows]);
            cninv[j0 + l] = if n > 0.0 { 1.0 / n } else { 0.0 };
        }
    })?;
    Ok(cninv)
}

/// Streaming Algorithm 1: [`crate::solver::solve_bak`] over chunks.
/// Bit-identical to the in-memory run for any chunk width.
pub fn solve_bak_stream(
    x: &StreamedMatrix,
    y: &[f32],
    opts: &SolveOptions,
) -> Result<StreamReport, SolverError> {
    validate(x, y, opts)?;
    solve_bak_stream_warm(x, y, vec![0.0f32; x.cols()], y.to_vec(), opts)
}

/// Warm-start variant of [`solve_bak_stream`]: continues from a
/// caller-provided iterate and residual — the checkpoint/resume path. The
/// caller must guarantee `e0 == y - X a0`; the residual is carried
/// explicitly (never recomputed from `a0`) so a resumed run replays the
/// exact f32 state of the interrupted one and stays bit-identical to an
/// uninterrupted solve.
pub fn solve_bak_stream_warm(
    x: &StreamedMatrix,
    y: &[f32],
    a0: Vec<f32>,
    e0: Vec<f32>,
    opts: &SolveOptions,
) -> Result<StreamReport, SolverError> {
    validate(x, y, opts)?;
    let (rows, vars) = x.shape();
    if a0.len() != vars || e0.len() != rows {
        return Err(SolverError::Shape(format!(
            "warm state ({} coeffs, {} residuals) does not match streamed matrix {rows}x{vars}",
            a0.len(),
            e0.len()
        )));
    }
    let stream = start_stream(x)?;
    let cninv = streamed_colnorms_inv(&stream, vars)?;

    let mut a = a0;
    let mut e = e0;
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        pass(&stream, |j0, width, data| {
            for l in 0..width {
                let j = j0 + l;
                let cn = cninv[j];
                if cn == 0.0 {
                    continue; // zero column
                }
                let da = blas1::cd_step(&data[l * rows..(l + 1) * rows], &mut e, cn);
                a[j] += da;
            }
        })?;
        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(&e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, &a, &e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    let stats = stream.stats();
    stream.stop();
    Ok(StreamReport {
        report: SolveReport { a, e, history, y_norm_sq, sweeps, stop },
        stats,
    })
}

/// Streaming multi-RHS BAK: [`crate::solver::solve_bak_multi`] over
/// chunks — one chunk load serves every RHS. Bit-identical per RHS.
pub fn solve_bak_multi_stream(
    x: &StreamedMatrix,
    ys: &[Vec<f32>],
    opts: &SolveOptions,
) -> Result<StreamMultiReport, SolverError> {
    let (rows, vars) = x.shape();
    for y in ys {
        validate(x, y, opts)?;
    }
    if ys.is_empty() {
        return Ok(StreamMultiReport { reports: Vec::new(), stats: StreamStatsSnapshot::default() });
    }
    let nrhs = ys.len();
    let stream = start_stream(x)?;
    let cninv = streamed_colnorms_inv(&stream, vars)?;

    let mut a: Vec<Vec<f32>> = vec![vec![0.0f32; vars]; nrhs];
    let mut e: Vec<Vec<f32>> = ys.to_vec();
    let y_norm_sq: Vec<f64> = ys.iter().map(|y| blas1::sum_sq_f64(y)).collect();
    let mut history: Vec<Vec<f64>> = vec![Vec::new(); nrhs];
    let mut done: Vec<Option<StopReason>> = vec![None; nrhs];
    let mut prev_r2 = vec![f64::INFINITY; nrhs];
    let mut sweeps_done = vec![0usize; nrhs];
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        if done.iter().all(Option::is_some) {
            break;
        }
        pass(&stream, |j0, width, data| {
            for l in 0..width {
                let j = j0 + l;
                let cn = cninv[j];
                if cn == 0.0 {
                    continue;
                }
                let xj = &data[l * rows..(l + 1) * rows];
                for r in 0..nrhs {
                    if done[r].is_some() {
                        continue;
                    }
                    let da = blas1::dot(xj, &e[r]) * cn;
                    blas1::axpy(-da, xj, &mut e[r]);
                    a[r][j] += da;
                }
            }
        })?;
        for r in 0..nrhs {
            if done[r].is_some() {
                continue;
            }
            sweeps_done[r] = sweep + 1;
            let r2 = blas1::sum_sq_f64(&e[r]);
            history[r].push(r2);
            if r == 0 {
                // Like the in-memory multi-RHS solver: the probe follows the
                // first system's trajectory.
                opts.probe.observe(sweeps_done[r], r2, t0);
                if r2.is_finite() {
                    opts.probe.observe_state(sweeps_done[r], &a[r], &e[r], r2);
                }
            }
            if !r2.is_finite() {
                done[r] = Some(StopReason::Breakdown);
            } else if opts.tol > 0.0 && r2 <= opts.tol * opts.tol * y_norm_sq[r] {
                done[r] = Some(StopReason::Converged);
            } else if r2 >= prev_r2[r] * (1.0 - 1e-9) && sweep > 0 {
                done[r] = Some(StopReason::Stalled);
            }
            prev_r2[r] = r2;
        }
        if opts.cancel.is_cancelled() {
            for d in done.iter_mut() {
                if d.is_none() {
                    *d = Some(StopReason::Cancelled);
                }
            }
            break;
        }
    }

    let stats = stream.stats();
    stream.stop();
    let reports = (0..nrhs)
        .map(|r| SolveReport {
            a: std::mem::take(&mut a[r]),
            e: std::mem::take(&mut e[r]),
            history: std::mem::take(&mut history[r]),
            y_norm_sq: y_norm_sq[r],
            sweeps: sweeps_done[r],
            stop: done[r].unwrap_or(StopReason::MaxSweeps),
        })
        .collect();
    Ok(StreamMultiReport { reports, stats })
}

/// `e = y - X a` by streamed column accumulation: the same per-element
/// `mul_add` order as [`crate::linalg::residual`]'s gemv (serial and
/// threaded branches are elementwise identical).
fn streamed_residual(
    stream: &ChunkStream,
    y: &[f32],
    a: &[f32],
) -> Result<Vec<f32>, SolverError> {
    let rows = stream.rows();
    let mut acc = vec![0.0f32; rows];
    pass(stream, |j0, width, data| {
        for l in 0..width {
            let aj = a[j0 + l];
            if aj != 0.0 {
                blas1::axpy(aj, &data[l * rows..(l + 1) * rows], &mut acc);
            }
        }
    })?;
    Ok(y.iter().zip(&acc).map(|(&yi, &xi)| yi - xi).collect())
}

/// Streaming randomized Kaczmarz: [`crate::solver::solve_kaczmarz`] with
/// hoisted row draws and batched sequential row gathers. Bit-identical to
/// the in-memory run (same seed) for any chunk width and batch size.
pub fn solve_kaczmarz_stream(
    x: &StreamedMatrix,
    y: &[f32],
    opts: &SolveOptions,
) -> Result<StreamReport, SolverError> {
    validate(x, y, opts)?;
    let (obs, vars) = x.shape();
    let mut rng = Rng::seed(opts.seed);
    let stream = start_stream(x)?;

    // ||row_i||^2 in one chunk pass, columns in global order — the same
    // `mul_add` sequence as the in-memory column-major pass.
    let mut row_norms_sq = vec![0.0f32; obs];
    pass(&stream, |_j0, width, data| {
        for l in 0..width {
            for (rn, &v) in row_norms_sq.iter_mut().zip(&data[l * obs..(l + 1) * obs]) {
                *rn = v.mul_add(v, *rn);
            }
        }
    })?;
    let total: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
    let y_norm_sq = blas1::sum_sq_f64(y);
    if total == 0.0 {
        let stats = stream.stats();
        stream.stop();
        let stop = if y_norm_sq == 0.0 { StopReason::Converged } else { StopReason::Stalled };
        return Ok(StreamReport {
            report: SolveReport {
                a: vec![0.0f32; vars],
                e: y.to_vec(),
                history: vec![y_norm_sq],
                y_norm_sq,
                sweeps: 0,
                stop,
            },
            stats,
        });
    }
    let mut cdf = Vec::with_capacity(obs);
    let mut acc = 0.0f64;
    for &v in &row_norms_sq {
        acc += v as f64 / total;
        cdf.push(acc);
    }

    // Row-gather batches capped at half the byte budget (the other half
    // bounds the chunk buffer pool).
    let rows_per_batch = ((x.mem_budget() / 2) / (vars * 4).max(1)).max(1);

    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let mut draws = Vec::with_capacity(obs);
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        // Hoist the sweep's RNG draws: the in-memory loop consumes exactly
        // one uniform() per projection and nothing else, so drawing them
        // up front replays the identical sequence.
        draws.clear();
        for _ in 0..obs {
            let u = rng.uniform();
            let i = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(k) => k,
                Err(k) => k.min(obs - 1),
            };
            draws.push(i);
        }

        // Gather-and-replay in batches: each batch gathers its distinct
        // sampled rows in one sequential pass, then replays that batch's
        // projections in draw order (projections read the matrix, never
        // write it, so gathers are iterate-independent).
        let mut pos = 0;
        while pos < draws.len() {
            let mut slots: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
            let mut end = pos;
            while end < draws.len() {
                let i = draws[end];
                if row_norms_sq[i] != 0.0 && !slots.contains_key(&i) {
                    if slots.len() == rows_per_batch {
                        break;
                    }
                    slots.insert(i, slots.len());
                }
                end += 1;
            }
            let mut gather = vec![0.0f32; slots.len() * vars];
            pass(&stream, |j0, width, data| {
                for (&row, &slot) in &slots {
                    for l in 0..width {
                        gather[slot * vars + j0 + l] = data[l * obs + row];
                    }
                }
            })?;
            for &i in &draws[pos..end] {
                let nrm = row_norms_sq[i];
                if nrm == 0.0 {
                    continue;
                }
                let slot = slots[&i];
                let row = &gather[slot * vars..(slot + 1) * vars];
                let ri = y[i] - blas1::dot_strided(row, 1, &a);
                blas1::axpy_strided(ri / nrm, row, 1, &mut a);
            }
            pos = end;
        }

        sweeps = sweep + 1;
        let e = streamed_residual(&stream, y, &a)?;
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    let e = streamed_residual(&stream, y, &a)?;
    let stats = stream.stats();
    stream.stop();
    Ok(StreamReport {
        report: SolveReport { a, e, history, y_norm_sq, sweeps, stop },
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::solver::{solve_bak, solve_bak_multi, solve_kaczmarz};
    use crate::stream::format::{temp_chunk_path, write_chunked_dense};

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y)
    }

    fn on_disk(x: &Mat, chunk: usize, budget: usize) -> (StreamedMatrix, std::path::PathBuf) {
        let path = temp_chunk_path("solve");
        write_chunked_dense(x, chunk, &path).unwrap();
        (StreamedMatrix::open(&path).unwrap().with_budget(budget), path)
    }

    // The satellite-3 agreement matrix: chunk width 1, a non-divisor (7),
    // and an exact divisor of vars.
    const CHUNKS: [usize; 3] = [1, 7, 5];

    #[test]
    fn bak_stream_bit_identical_across_chunk_sizes() {
        let (x, y) = planted(900, 120, 20);
        let opts = SolveOptions::builder().max_sweeps(40).tol(1e-6).build();
        let mem = solve_bak(&x, &y, &opts);
        for &chunk in &CHUNKS {
            let (m, path) = on_disk(&x, chunk, 1 << 20);
            let got = solve_bak_stream(&m, &y, &opts).unwrap();
            assert_eq!(got.report.a, mem.a, "chunk={chunk}");
            assert_eq!(got.report.e, mem.e, "chunk={chunk}");
            assert_eq!(got.report.history, mem.history, "chunk={chunk}");
            assert_eq!(got.report.sweeps, mem.sweeps, "chunk={chunk}");
            assert_eq!(got.report.stop, mem.stop, "chunk={chunk}");
            assert!(got.stats.chunks_read > 0 && got.stats.bytes_read > 0);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn bak_stream_solves_matrix_bigger_than_budget() {
        // The acceptance-criteria shape: X bytes >> buffer-pool budget.
        let (x, y) = planted(901, 600, 40);
        let budget = 16 * 1024; // 16 KiB pool vs 93.75 KiB matrix
        let (m, path) = on_disk(&x, 4, budget);
        assert!(m.nbytes() > budget, "workload must exceed the budget");
        let opts = SolveOptions::accurate();
        let got = solve_bak_stream(&m, &y, &opts).unwrap();
        let mem = solve_bak(&x, &y, &opts);
        assert_eq!(got.report.a, mem.a);
        assert!(got.report.rel_residual() < 1e-5);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn kaczmarz_stream_bit_identical_across_chunk_sizes() {
        let (x, y) = planted(902, 60, 20);
        let mut opts = SolveOptions::default();
        opts.max_sweeps = 8;
        opts.tol = 1e-6;
        let mem = solve_kaczmarz(&x, &y, &opts);
        for &chunk in &CHUNKS {
            let (m, path) = on_disk(&x, chunk, 1 << 20);
            let got = solve_kaczmarz_stream(&m, &y, &opts).unwrap();
            assert_eq!(got.report.a, mem.a, "chunk={chunk}");
            assert_eq!(got.report.e, mem.e, "chunk={chunk}");
            assert_eq!(got.report.history, mem.history, "chunk={chunk}");
            assert_eq!(got.report.stop, mem.stop, "chunk={chunk}");
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn kaczmarz_stream_identical_with_tiny_gather_batches() {
        // A budget so small every sweep needs many gather passes; the
        // replay order (hence the arithmetic) must not change.
        let (x, y) = planted(903, 40, 12);
        let mut opts = SolveOptions::default();
        opts.max_sweeps = 4;
        opts.tol = 0.0;
        let mem = solve_kaczmarz(&x, &y, &opts);
        let (m, path) = on_disk(&x, 3, 1); // floor: 1 row per gather batch
        let got = solve_kaczmarz_stream(&m, &y, &opts).unwrap();
        assert_eq!(got.report.a, mem.a);
        assert_eq!(got.report.history, mem.history);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn kaczmarz_stream_zero_matrix_early_return() {
        let x = Mat::zeros(5, 3);
        let (m, path) = on_disk(&x, 2, 1 << 16);
        let got = solve_kaczmarz_stream(&m, &[1.0; 5], &SolveOptions::default()).unwrap();
        assert_eq!(got.report.a, vec![0.0; 3]);
        assert_eq!(got.report.stop, StopReason::Stalled);
        let got = solve_kaczmarz_stream(&m, &[0.0; 5], &SolveOptions::default()).unwrap();
        assert_eq!(got.report.stop, StopReason::Converged);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn multi_stream_bit_identical_per_rhs() {
        let (x, _) = planted(904, 90, 15);
        let mut rng = Rng::seed(905);
        let ys: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let a: Vec<f32> = (0..15).map(|_| rng.normal_f32()).collect();
                x.matvec(&a)
            })
            .collect();
        let opts = SolveOptions::builder().max_sweeps(30).tol(1e-6).build();
        let mem = solve_bak_multi(&x, &ys, &opts);
        for &chunk in &CHUNKS {
            let (m, path) = on_disk(&x, chunk, 1 << 20);
            let got = solve_bak_multi_stream(&m, &ys, &opts).unwrap();
            assert_eq!(got.reports.len(), 3);
            for r in 0..3 {
                assert_eq!(got.reports[r].a, mem[r].a, "chunk={chunk} rhs={r}");
                assert_eq!(got.reports[r].e, mem[r].e, "chunk={chunk} rhs={r}");
                assert_eq!(got.reports[r].history, mem[r].history, "chunk={chunk} rhs={r}");
                assert_eq!(got.reports[r].stop, mem[r].stop, "chunk={chunk} rhs={r}");
            }
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn multi_stream_empty_rhs_list() {
        let (x, _) = planted(906, 10, 4);
        let (m, path) = on_disk(&x, 2, 1 << 16);
        let got = solve_bak_multi_stream(&m, &[], &SolveOptions::default()).unwrap();
        assert!(got.reports.is_empty());
        assert_eq!(got.stats, StreamStatsSnapshot::default());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn shuffled_order_rejected_with_typed_error() {
        let (x, y) = planted(907, 20, 5);
        let (m, path) = on_disk(&x, 2, 1 << 16);
        let mut opts = SolveOptions::default();
        opts.order = ColumnOrder::Shuffled;
        let err = solve_bak_stream(&m, &y, &opts).unwrap_err();
        assert!(matches!(err, SolverError::InvalidInput(_)), "{err:?}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (x, _) = planted(908, 20, 5);
        let (m, path) = on_disk(&x, 2, 1 << 16);
        let err = solve_bak_stream(&m, &[1.0; 7], &SolveOptions::default()).unwrap_err();
        assert!(matches!(err, SolverError::Shape(_)), "{err:?}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stats_count_passes() {
        let (x, y) = planted(909, 30, 8);
        let (m, path) = on_disk(&x, 4, 1 << 16);
        let opts = SolveOptions::builder().max_sweeps(3).tol(0.0).build();
        let got = solve_bak_stream(&m, &y, &opts).unwrap();
        // colnorms pass + 3 sweeps = 4 consumed passes of 2 chunks; the
        // prefetcher may have read a few chunks ahead before stopping.
        assert!(got.stats.chunks_read >= 8, "{:?}", got.stats);
        assert!(got.stats.bytes_read >= (30 * 8 * 4 * 4) as u64);
        let _ = std::fs::remove_file(path);
    }
}
