//! The on-disk chunked matrix format and its reader/writer.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  0: magic  b"SBCK"                  (4 bytes)
//! offset  4: format version                  (1 byte, currently 2)
//! offset  5: reserved zero padding           (3 bytes)
//! offset  8: rows       u64
//! offset 16: cols       u64
//! offset 24: chunk_cols u64   (columns per chunk; last chunk may be narrower)
//! offset 32: payload — per chunk: rows*width f32 values, column-major (the
//!            exact byte image of [`Mat::as_slice`] for those columns),
//!            followed (v2) by the CRC32 (u32 LE) of that chunk's payload
//!            bytes
//! ```
//!
//! Whole-column chunks are the point: a chunk-resident column is the same
//! contiguous `&[f32]` slice the in-memory solvers feed to
//! [`crate::linalg::blas1`], so the streamed inner steps replay the
//! identical f32 operations (see [`super::solve`]).
//!
//! Version history: v1 had no per-chunk checksum; v2 appends a CRC32
//! integrity word after every chunk, verified on every read (sync passes
//! and the prefetch pipeline alike) so a flipped bit surfaces as a typed
//! corruption error instead of silently wrong math. Readers accept v1 for
//! compatibility — v1 chunks are simply not checksummed.
//!
//! The version byte is the compatibility contract: readers reject any
//! version they do not know (see CONTRIBUTING.md); bump it on any layout
//! change.

use std::fs::File;
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::linalg::{blas1, Mat};
use crate::sparse::CscMat;

/// File magic: "SolveBak ChunKs".
pub const MAGIC: [u8; 4] = *b"SBCK";
/// Current format version (the byte at offset 4). v2 = per-chunk CRC32.
pub const FORMAT_VERSION: u8 = 2;
/// Oldest format version readers still accept (v1 = no chunk checksums).
pub const MIN_FORMAT_VERSION: u8 = 1;
/// Header length in bytes; the payload starts here.
pub const HEADER_LEN: u64 = 32;
/// Default buffer-pool byte budget when the caller does not set one.
pub const DEFAULT_MEM_BUDGET: usize = 8 << 20; // 8 MiB

/// Chunk width targeting ~1 MiB chunks: small enough that the
/// double-buffered pool fits comfortable budgets, large enough that reads
/// are sequential-friendly.
pub fn default_chunk_cols(rows: usize, cols: usize) -> usize {
    let per_col = (rows * 4).max(1);
    ((1usize << 20) / per_col).clamp(1, cols.max(1))
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// A chunk whose stored CRC32 does not match its payload. Travels as the
/// inner error of an `InvalidData` [`io::Error`] through the prefetch
/// pipeline; [`super::solve`] downcasts it back out to produce the typed
/// `SolverError::CorruptData` the wire protocol reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CorruptChunk {
    /// Zero-based chunk index.
    pub chunk: usize,
    /// CRC32 stored in the file.
    pub expected: u32,
    /// CRC32 computed over the bytes actually read.
    pub actual: u32,
}

impl std::fmt::Display for CorruptChunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "chunk {} corrupt: stored crc32 {:#010x}, computed {:#010x}",
            self.chunk, self.expected, self.actual
        )
    }
}

impl std::error::Error for CorruptChunk {}

fn write_header(w: &mut impl Write, rows: usize, cols: usize, chunk_cols: usize) -> io::Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&[FORMAT_VERSION, 0, 0, 0])?;
    w.write_all(&(rows as u64).to_le_bytes())?;
    w.write_all(&(cols as u64).to_le_bytes())?;
    w.write_all(&(chunk_cols as u64).to_le_bytes())?;
    Ok(())
}

/// Write a chunked file whose columns are produced on the fly:
/// `fill(start_col, width, buf)` must fill `buf` (rows*width, column-major)
/// with columns [start_col, start_col+width). This is the out-of-core
/// generation path — peak memory is one chunk, never the full matrix.
pub fn write_chunked_with(
    path: &Path,
    rows: usize,
    cols: usize,
    chunk_cols: usize,
    mut fill: impl FnMut(usize, usize, &mut [f32]),
) -> io::Result<()> {
    assert!(chunk_cols >= 1, "chunk_cols must be >= 1");
    let mut w = BufWriter::new(File::create(path)?);
    write_header(&mut w, rows, cols, chunk_cols)?;
    let mut buf = vec![0.0f32; rows * chunk_cols];
    let mut bytes = Vec::with_capacity(rows * chunk_cols * 4);
    let mut j0 = 0;
    while j0 < cols {
        let width = chunk_cols.min(cols - j0);
        let chunk = &mut buf[..rows * width];
        chunk.fill(0.0);
        fill(j0, width, chunk);
        bytes.clear();
        for &v in chunk.iter() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&bytes)?;
        // v2: per-chunk integrity word, CRC32 of the payload bytes just
        // written.
        w.write_all(&crate::util::crc32::crc32(&bytes).to_le_bytes())?;
        j0 += width;
    }
    w.flush()
}

/// Convert an in-memory dense matrix to a chunked file.
pub fn write_chunked_dense(x: &Mat, chunk_cols: usize, path: &Path) -> io::Result<()> {
    let (rows, _) = x.shape();
    write_chunked_with(path, x.rows(), x.cols(), chunk_cols, |j0, width, buf| {
        buf.copy_from_slice(&x.as_slice()[j0 * rows..(j0 + width) * rows]);
    })
}

/// Convert a sparse (CSC) matrix to a chunked (dense-payload) file. COO
/// inputs go through [`crate::sparse::CooBuilder`] first, which validates
/// triplets and sums duplicates.
pub fn write_chunked_csc(x: &CscMat, chunk_cols: usize, path: &Path) -> io::Result<()> {
    let rows = x.rows();
    write_chunked_with(path, rows, x.cols(), chunk_cols, |j0, width, buf| {
        for l in 0..width {
            let (idx, vals) = x.col(j0 + l);
            for (&i, &v) in idx.iter().zip(vals) {
                buf[l * rows + i] = v;
            }
        }
    })
}

/// Write a raw f32-LE vector sidecar (the CLI's `<x>.y` right-hand side).
pub fn write_vec_f32(path: &Path, v: &[f32]) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let mut bytes = Vec::with_capacity(v.len() * 4);
    for &x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    w.flush()
}

/// Read a raw f32-LE vector sidecar written by [`write_vec_f32`].
pub fn read_vec_f32(path: &Path) -> io::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(invalid(format!("{}: length {} not a multiple of 4", path.display(), bytes.len())));
    }
    Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

/// Handle to an on-disk chunked matrix: the header metadata plus the
/// buffer-pool byte budget used when streaming it. Cheap to clone/share;
/// actual I/O happens through [`StreamedMatrix::reader`] /
/// [`super::ChunkStream`].
#[derive(Debug)]
pub struct StreamedMatrix {
    path: PathBuf,
    rows: usize,
    cols: usize,
    chunk_cols: usize,
    /// On-disk format version ([`MIN_FORMAT_VERSION`]..=[`FORMAT_VERSION`]).
    version: u8,
    /// Buffer-pool byte budget; 0 means [`DEFAULT_MEM_BUDGET`].
    mem_budget: usize,
}

impl StreamedMatrix {
    /// Open and validate a chunked file (magic, version, payload length).
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut f = File::open(&path)?;
        let mut header = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut header)
            .map_err(|_| invalid(format!("{}: truncated header", path.display())))?;
        if header[..4] != MAGIC {
            return Err(invalid(format!("{}: not a chunked matrix (bad magic)", path.display())));
        }
        let version = header[4];
        if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
            return Err(invalid(format!(
                "{}: unsupported chunk format version {version} (expected {MIN_FORMAT_VERSION}..={FORMAT_VERSION})",
                path.display()
            )));
        }
        let u64_at = |o: usize| u64::from_le_bytes(header[o..o + 8].try_into().unwrap());
        let (rows, cols, chunk_cols) = (u64_at(8) as usize, u64_at(16) as usize, u64_at(24) as usize);
        if cols > 0 && chunk_cols == 0 {
            return Err(invalid(format!("{}: chunk_cols must be >= 1", path.display())));
        }
        let num_chunks =
            if cols == 0 { 0u64 } else { cols.div_ceil(chunk_cols.max(1)) as u64 };
        // v2 appends a 4-byte CRC32 after every chunk; v1 is bare payload.
        let want = HEADER_LEN
            + (rows * cols * 4) as u64
            + if version >= 2 { num_chunks * 4 } else { 0 };
        let got = f.metadata()?.len();
        if got != want {
            return Err(invalid(format!(
                "{}: payload length mismatch (file {got} bytes, header implies {want})",
                path.display()
            )));
        }
        Ok(Self { path, rows, cols, chunk_cols: chunk_cols.max(1), version, mem_budget: 0 })
    }

    /// Set the buffer-pool byte budget (0 restores the default).
    pub fn with_budget(mut self, bytes: usize) -> Self {
        self.mem_budget = bytes;
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Columns per chunk (the last chunk may be narrower).
    #[inline]
    pub fn chunk_cols(&self) -> usize {
        self.chunk_cols
    }

    /// On-disk format version byte (1 = no chunk checksums, 2 = CRC32 per
    /// chunk).
    #[inline]
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Number of chunks; `cols` is never padded, so an exact divisor means
    /// no empty trailing chunk.
    #[inline]
    pub fn num_chunks(&self) -> usize {
        if self.cols == 0 { 0 } else { self.cols.div_ceil(self.chunk_cols) }
    }

    /// Width (columns) of chunk `c`.
    #[inline]
    pub fn chunk_width(&self, c: usize) -> usize {
        debug_assert!(c < self.num_chunks());
        self.chunk_cols.min(self.cols - c * self.chunk_cols)
    }

    /// Payload bytes of the full matrix (what an in-memory [`Mat`] would
    /// occupy).
    pub fn nbytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Effective buffer-pool budget in bytes.
    pub fn mem_budget(&self) -> usize {
        if self.mem_budget == 0 { DEFAULT_MEM_BUDGET } else { self.mem_budget }
    }

    /// Open a sequential chunk reader over this file.
    pub fn reader(&self) -> io::Result<FileChunkSource> {
        FileChunkSource::open(self)
    }

    /// One synchronous pass over every chunk in order (no prefetch thread);
    /// `f(start_col, width, data)` sees rows×width column-major data.
    pub fn for_each_chunk(&self, mut f: impl FnMut(usize, usize, &[f32])) -> io::Result<()> {
        let mut src = self.reader()?;
        let mut buf = Vec::new();
        for c in 0..self.num_chunks() {
            let width = src.read_chunk(c, &mut buf)?;
            f(c * self.chunk_cols, width, &buf);
        }
        Ok(())
    }

    /// Materialise the full matrix in memory. This defeats the purpose of
    /// streaming — it exists for tests, conversion round-trips, and the
    /// explicit [`crate::api::MatrixRef::to_dense`] escape hatch.
    pub fn to_mat(&self) -> io::Result<Mat> {
        let mut data = vec![0.0f32; self.rows * self.cols];
        self.for_each_chunk(|j0, _width, chunk| {
            data[j0 * self.rows..j0 * self.rows + chunk.len()].copy_from_slice(chunk);
        })?;
        Ok(Mat::from_col_major(self.rows, self.cols, data))
    }

    /// y = X a by streaming column accumulation — the same per-element
    /// `mul_add` order as the in-memory [`crate::linalg::blas2::gemv`].
    /// Panics on I/O errors (use the solver entry points for typed errors).
    pub fn matvec(&self, a: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.cols, "matvec dim mismatch");
        let mut acc = vec![0.0f32; self.rows];
        self.for_each_chunk(|j0, width, chunk| {
            for l in 0..width {
                let aj = a[j0 + l];
                if aj != 0.0 {
                    blas1::axpy(aj, &chunk[l * self.rows..(l + 1) * self.rows], &mut acc);
                }
            }
        })
        .expect("streamed matvec: chunk read failed");
        acc
    }

    /// out = Xᵀ v by streaming per-column dots. Panics on I/O errors.
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows, "matvec_t dim mismatch");
        let mut out = vec![0.0f32; self.cols];
        self.for_each_chunk(|j0, width, chunk| {
            for l in 0..width {
                out[j0 + l] = blas1::dot(&chunk[l * self.rows..(l + 1) * self.rows], v);
            }
        })
        .expect("streamed matvec_t: chunk read failed");
        out
    }

    /// <x_j, x_j> for every column — bit-identical to
    /// [`Mat::colnorms_sq`] (same `nrm2_sq` on the same column slices).
    /// Panics on I/O errors.
    pub fn colnorms_sq(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        self.for_each_chunk(|j0, width, chunk| {
            for l in 0..width {
                out[j0 + l] = blas1::nrm2_sq(&chunk[l * self.rows..(l + 1) * self.rows]);
            }
        })
        .expect("streamed colnorms_sq: chunk read failed");
        out
    }
}

/// A source of column-major chunks, read by index. The prefetch pipeline
/// ([`super::ChunkStream`]) drives one of these from its reader thread;
/// synchronous passes use it directly.
pub trait ChunkSource: Send {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// Columns per chunk (last chunk may be narrower).
    fn chunk_cols(&self) -> usize;
    fn num_chunks(&self) -> usize {
        if self.cols() == 0 { 0 } else { self.cols().div_ceil(self.chunk_cols().max(1)) }
    }
    /// Fill `buf` with chunk `c` (column-major, rows × width) and return
    /// the chunk's width.
    fn read_chunk(&mut self, c: usize, buf: &mut Vec<f32>) -> io::Result<usize>;
}

/// [`ChunkSource`] over a chunked file: seek + buffered `read_exact` per
/// chunk (std-only; no mmap in the offline toolchain).
pub struct FileChunkSource {
    file: File,
    rows: usize,
    cols: usize,
    chunk_cols: usize,
    version: u8,
    /// Reused raw-byte scratch for one chunk.
    scratch: Vec<u8>,
}

impl FileChunkSource {
    fn open(m: &StreamedMatrix) -> io::Result<Self> {
        Ok(Self {
            file: File::open(m.path())?,
            rows: m.rows(),
            cols: m.cols(),
            chunk_cols: m.chunk_cols(),
            version: m.version(),
            scratch: Vec::new(),
        })
    }
}

impl ChunkSource for FileChunkSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn chunk_cols(&self) -> usize {
        self.chunk_cols
    }

    fn read_chunk(&mut self, c: usize, buf: &mut Vec<f32>) -> io::Result<usize> {
        assert!(c < self.num_chunks(), "chunk {c} out of range");
        let start_col = c * self.chunk_cols;
        let width = self.chunk_cols.min(self.cols - start_col);
        let nbytes = self.rows * width * 4;
        self.scratch.resize(nbytes, 0);
        // v2 files carry 4 CRC bytes after every chunk, so chunk c's
        // payload starts 4*c bytes later than the bare v1 layout.
        let crc_skew = if self.version >= 2 { (c * 4) as u64 } else { 0 };
        self.file
            .seek(SeekFrom::Start(HEADER_LEN + (start_col * self.rows * 4) as u64 + crc_skew))?;
        self.file.read_exact(&mut self.scratch)?;
        // Chaos hook: flip one payload byte after the read, before the CRC
        // check — exactly the corruption v2's integrity word exists to
        // catch (v1 files, having no checksum, pass it through silently).
        if crate::robust::faults::corrupt_chunk() {
            if let Some(b) = self.scratch.get_mut(nbytes / 2) {
                *b ^= 0x40;
            }
        }
        if self.version >= 2 {
            let mut crc_bytes = [0u8; 4];
            self.file.read_exact(&mut crc_bytes)?;
            let expected = u32::from_le_bytes(crc_bytes);
            let actual = crate::util::crc32::crc32(&self.scratch);
            if actual != expected {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    CorruptChunk { chunk: c, expected, actual },
                ));
            }
        }
        buf.clear();
        buf.reserve(self.rows * width);
        buf.extend(
            self.scratch
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        );
        Ok(width)
    }
}

/// A fresh temp-file path for tests and synthetic conversions (unique per
/// process + call; no external tempfile crate offline).
pub fn temp_chunk_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("solvebak_{tag}_{}_{n}.sbck", std::process::id()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::rng::Rng;

    fn randmat(seed: u64, rows: usize, cols: usize) -> Mat {
        let mut rng = Rng::seed(seed);
        Mat::randn(&mut rng, rows, cols)
    }

    fn roundtrip(rows: usize, cols: usize, chunk: usize) -> (Mat, StreamedMatrix, PathBuf) {
        let x = randmat(1000 + rows as u64 + cols as u64 + chunk as u64, rows, cols);
        let path = temp_chunk_path("fmt");
        write_chunked_dense(&x, chunk, &path).unwrap();
        let m = StreamedMatrix::open(&path).unwrap();
        (x, m, path)
    }

    #[test]
    fn dense_roundtrip_exact() {
        for &(rows, cols, chunk) in &[(11usize, 7usize, 3usize), (5, 5, 5), (8, 6, 2), (3, 1, 1)] {
            let (x, m, path) = roundtrip(rows, cols, chunk);
            assert_eq!(m.shape(), (rows, cols));
            assert_eq!(m.to_mat().unwrap(), x, "rows={rows} cols={cols} chunk={chunk}");
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn chunk_count_indivisible_width() {
        // 7 cols, chunk 3 -> widths 3, 3, 1.
        let (_, m, path) = roundtrip(4, 7, 3);
        assert_eq!(m.num_chunks(), 3);
        assert_eq!(m.chunk_width(0), 3);
        assert_eq!(m.chunk_width(1), 3);
        assert_eq!(m.chunk_width(2), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn chunk_count_exact_divisor_has_no_empty_trailing_chunk() {
        let (_, m, path) = roundtrip(4, 6, 3);
        assert_eq!(m.num_chunks(), 2);
        assert_eq!(m.chunk_width(0), 3);
        assert_eq!(m.chunk_width(1), 3);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn single_chunk_matrix() {
        // chunk >= cols: everything in one chunk.
        let (_, m, path) = roundtrip(5, 4, 9);
        assert_eq!(m.num_chunks(), 1);
        assert_eq!(m.chunk_width(0), 4);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn chunk_width_one_yields_one_chunk_per_column() {
        let (x, m, path) = roundtrip(6, 5, 1);
        assert_eq!(m.num_chunks(), 5);
        let mut seen = Vec::new();
        m.for_each_chunk(|j0, width, data| {
            assert_eq!(width, 1);
            assert_eq!(data, x.col(j0));
            seen.push(j0);
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn csc_converter_matches_dense_payload() {
        let mut b = CooBuilder::new(6, 4);
        b.push(0, 0, 1.5);
        b.push(5, 0, -2.0);
        b.push(2, 2, 3.25);
        b.push(2, 2, 0.75); // duplicate summed -> 4.0
        b.push(1, 3, 7.0);
        let csc = b.to_csc();
        let path = temp_chunk_path("csc");
        write_chunked_csc(&csc, 3, &path).unwrap();
        let m = StreamedMatrix::open(&path).unwrap();
        assert_eq!(m.to_mat().unwrap(), csc.to_dense());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_matvec_and_colnorms_match_dense() {
        let (x, m, path) = roundtrip(16, 10, 4);
        let a: Vec<f32> = (0..10).map(|i| (i as f32 - 4.5) * 0.3).collect();
        assert_eq!(m.matvec(&a), x.matvec(&a));
        let v: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        assert_eq!(m.matvec_t(&v), x.matvec_t(&v));
        assert_eq!(m.colnorms_sq(), x.colnorms_sq());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn open_rejects_bad_magic_version_and_length() {
        let (_, m, path) = roundtrip(3, 3, 2);
        drop(m);
        let good = std::fs::read(&path).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(StreamedMatrix::open(&path).is_err(), "bad magic accepted");

        let mut bad = good.clone();
        bad[4] = FORMAT_VERSION + 1;
        std::fs::write(&path, &bad).unwrap();
        let err = StreamedMatrix::open(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        let mut bad = good.clone();
        bad.pop();
        std::fs::write(&path, &bad).unwrap();
        assert!(StreamedMatrix::open(&path).is_err(), "truncated payload accepted");

        std::fs::write(&path, &good[..8]).unwrap();
        assert!(StreamedMatrix::open(&path).is_err(), "truncated header accepted");
        let _ = std::fs::remove_file(path);
    }

    /// Hand-roll the legacy v1 layout: version byte 1, bare column-major
    /// payload, no per-chunk CRC words.
    fn write_v1_file(x: &Mat, chunk_cols: usize, path: &Path) {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&[1u8, 0, 0, 0]);
        bytes.extend_from_slice(&(x.rows() as u64).to_le_bytes());
        bytes.extend_from_slice(&(x.cols() as u64).to_le_bytes());
        bytes.extend_from_slice(&(chunk_cols as u64).to_le_bytes());
        for &v in x.as_slice() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn v1_files_still_readable_after_v2_bump() {
        let x = randmat(42, 9, 7);
        let path = temp_chunk_path("v1compat");
        write_v1_file(&x, 3, &path);
        let m = StreamedMatrix::open(&path).unwrap();
        assert_eq!(m.version(), 1);
        assert_eq!(m.shape(), (9, 7));
        assert_eq!(m.to_mat().unwrap(), x, "v1 payload reads back exactly");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fresh_files_are_v2_with_per_chunk_crc() {
        let (x, m, path) = roundtrip(8, 6, 2);
        assert_eq!(m.version(), FORMAT_VERSION);
        assert_eq!(m.to_mat().unwrap(), x);
        // Length accounts for one CRC word per chunk.
        let got = std::fs::metadata(&path).unwrap().len();
        assert_eq!(got, HEADER_LEN + (8 * 6 * 4) + 3 * 4);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn flipped_byte_in_v2_chunk_detected_as_corrupt() {
        let (_, m, path) = roundtrip(8, 6, 2);
        drop(m);
        let mut bytes = std::fs::read(&path).unwrap();
        // One bit inside chunk 1's payload (chunk 0 = 8*2 f32 + its CRC).
        let off = HEADER_LEN as usize + (8 * 2 * 4) + 4 + 3;
        bytes[off] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let m = StreamedMatrix::open(&path).unwrap(); // length still valid
        let err = m.to_mat().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let c = err
            .get_ref()
            .and_then(|i| i.downcast_ref::<CorruptChunk>())
            .expect("inner error must be CorruptChunk");
        assert_eq!(c.chunk, 1);
        assert_ne!(c.expected, c.actual);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn write_chunked_with_streams_generation() {
        // Generate column j = constant j without materialising the matrix.
        let path = temp_chunk_path("gen");
        write_chunked_with(&path, 4, 5, 2, |j0, width, buf| {
            for l in 0..width {
                buf[l * 4..(l + 1) * 4].fill((j0 + l) as f32);
            }
        })
        .unwrap();
        let m = StreamedMatrix::open(&path).unwrap();
        let mat = m.to_mat().unwrap();
        for j in 0..5 {
            assert!(mat.col(j).iter().all(|&v| v == j as f32));
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn vec_sidecar_roundtrip() {
        let path = temp_chunk_path("vec");
        let v: Vec<f32> = vec![1.0, -2.5, 3.25, f32::MIN_POSITIVE];
        write_vec_f32(&path, &v).unwrap();
        assert_eq!(read_vec_f32(&path).unwrap(), v);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn default_chunk_cols_bounds() {
        assert_eq!(default_chunk_cols(1 << 20, 100), 1); // huge rows -> 1 col
        assert_eq!(default_chunk_cols(4, 3), 3); // tiny matrix -> all cols
        assert!(default_chunk_cols(1024, 4096) >= 1);
    }

    #[test]
    fn budget_defaults_and_override() {
        let (_, m, path) = roundtrip(3, 3, 2);
        assert_eq!(m.mem_budget(), DEFAULT_MEM_BUDGET);
        let m = m.with_budget(1 << 16);
        assert_eq!(m.mem_budget(), 1 << 16);
        let _ = std::fs::remove_file(path);
    }
}
