//! Double-buffered chunk prefetch: a reader thread fills chunk buffers
//! from disk while the solver consumes the previous one.
//!
//! Backpressure and memory bounding both come from
//! [`crate::parallel::BoundedQueue`]: a fixed pool of `n` chunk buffers
//! circulates between a `recycle` queue (empty buffers, popped by the
//! reader) and a `data` queue (filled chunks, popped by the solver).
//! `n = clamp(budget / chunk_bytes, 2, 64)`, so peak resident payload is
//! at most `n * chunk_bytes` — bounded by the buffer-pool byte budget
//! (floor: two chunks, the minimum for double buffering) and measurable
//! via `/proc/self/status` VmHWM (see [`crate::util::alloc::peak_rss_bytes`]).
//!
//! The reader loops over the file pass after pass (every consumer pass —
//! colnorms, sweeps, gathers, residuals — reads all chunks in order), so
//! solvers with data-dependent sweep counts just stop consuming and call
//! [`ChunkStream::stop`]; the queues close and the reader exits at its
//! next push/pop.

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::parallel::BoundedQueue;

use super::format::{ChunkSource, StreamedMatrix};

/// Cumulative I/O counters for one stream (exported by the coordinator as
/// `stream_chunks_read` / `stream_bytes_read` / `stream_buffer_stalls`).
#[derive(Debug, Default)]
pub struct StreamStats {
    chunks_read: AtomicU64,
    bytes_read: AtomicU64,
    buffer_stalls: AtomicU64,
}

impl StreamStats {
    fn add_chunk(&self, bytes: u64) {
        self.chunks_read.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    }

    fn add_stall(&self) {
        self.buffer_stalls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StreamStatsSnapshot {
        StreamStatsSnapshot {
            chunks_read: self.chunks_read.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            buffer_stalls: self.buffer_stalls.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`StreamStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStatsSnapshot {
    /// Chunks delivered by the reader thread.
    pub chunks_read: u64,
    /// Payload bytes delivered.
    pub bytes_read: u64,
    /// Times the consumer found the data queue empty (reader behind —
    /// I/O-bound phases show up here).
    pub buffer_stalls: u64,
}

impl StreamStatsSnapshot {
    /// Elementwise sum (for aggregating multi-stream solves).
    pub fn merged(self, other: StreamStatsSnapshot) -> StreamStatsSnapshot {
        StreamStatsSnapshot {
            chunks_read: self.chunks_read + other.chunks_read,
            bytes_read: self.bytes_read + other.bytes_read,
            buffer_stalls: self.buffer_stalls + other.buffer_stalls,
        }
    }
}

/// One filled chunk: columns [start_col, start_col+width) of the matrix,
/// column-major in `data` (rows × width). Return `data` to the pool with
/// [`ChunkStream::recycle`] when done.
pub struct Chunk {
    pub index: usize,
    pub start_col: usize,
    pub width: usize,
    pub data: Vec<f32>,
}

/// The prefetch pipeline handle owned by the consuming solver.
pub struct ChunkStream {
    rows: usize,
    num_chunks: usize,
    data: Arc<BoundedQueue<Chunk>>,
    recycle: Arc<BoundedQueue<Vec<f32>>>,
    stats: Arc<StreamStats>,
    /// First I/O error hit by the reader (it closes `data` after storing).
    error: Arc<Mutex<Option<io::Error>>>,
    reader: Option<JoinHandle<()>>,
    buffers: usize,
}

impl ChunkStream {
    /// Spawn the reader thread over `m` with its configured byte budget.
    pub fn start(m: &StreamedMatrix) -> io::Result<Self> {
        let mut src = m.reader()?;
        let rows = m.rows();
        let chunk_cols = m.chunk_cols();
        let num_chunks = m.num_chunks();
        let chunk_bytes = (rows * chunk_cols * 4).max(1);
        let buffers = (m.mem_budget() / chunk_bytes).clamp(2, 64);

        let data = Arc::new(BoundedQueue::new(buffers));
        let recycle = Arc::new(BoundedQueue::new(buffers));
        for _ in 0..buffers {
            recycle.try_push(Vec::new()).ok().expect("fresh recycle queue has room");
        }
        let stats = Arc::new(StreamStats::default());
        let error = Arc::new(Mutex::new(None));

        let reader = {
            let (data, recycle) = (data.clone(), recycle.clone());
            let (stats, error) = (stats.clone(), error.clone());
            std::thread::Builder::new()
                .name("chunk-prefetch".into())
                .spawn(move || loop {
                    if num_chunks == 0 {
                        data.close();
                        return;
                    }
                    for c in 0..num_chunks {
                        let Some(mut buf) = recycle.pop() else { return }; // stopped
                        if let Some(d) = crate::robust::faults::slow_read_delay() {
                            std::thread::sleep(d);
                        }
                        match src.read_chunk(c, &mut buf) {
                            Ok(width) => {
                                stats.add_chunk((src.rows() * width * 4) as u64);
                                let chunk = Chunk {
                                    index: c,
                                    start_col: c * src.chunk_cols(),
                                    width,
                                    data: buf,
                                };
                                if data.push(chunk).is_err() {
                                    return; // stopped
                                }
                            }
                            Err(e) => {
                                *error.lock().unwrap() = Some(e);
                                data.close();
                                return;
                            }
                        }
                    }
                })
                .expect("spawn chunk-prefetch thread")
        };

        Ok(Self {
            rows,
            num_chunks,
            data,
            recycle,
            stats,
            error,
            reader: Some(reader),
            buffers,
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Chunks per full pass over the matrix.
    #[inline]
    pub fn num_chunks(&self) -> usize {
        self.num_chunks
    }

    /// Buffers in the pool (the budget-derived bound).
    pub fn buffers(&self) -> usize {
        self.buffers
    }

    /// Next chunk in pass order; `None` means the reader stopped on an
    /// I/O error (see [`ChunkStream::take_error`]). Blocks when the reader
    /// is behind, counting a buffer stall.
    pub fn next(&self) -> Option<Chunk> {
        if self.data.is_empty() {
            self.stats.add_stall();
        }
        self.data.pop()
    }

    /// Return a consumed chunk's buffer to the pool.
    pub fn recycle(&self, buf: Vec<f32>) {
        let _ = self.recycle.try_push(buf); // only fails once stopped
    }

    pub fn stats(&self) -> StreamStatsSnapshot {
        self.stats.snapshot()
    }

    /// The reader's I/O error, if it hit one.
    pub fn take_error(&self) -> Option<io::Error> {
        self.error.lock().unwrap().take()
    }

    /// Stop the reader and reclaim the thread.
    pub fn stop(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.data.close();
        self.recycle.close();
        let _ = self.data.drain_now(); // free any in-flight buffers
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChunkStream {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::stream::format::{temp_chunk_path, write_chunked_dense};
    use crate::util::rng::Rng;

    fn stream_over(rows: usize, cols: usize, chunk: usize, budget: usize) -> (Mat, ChunkStream, std::path::PathBuf) {
        let mut rng = Rng::seed(42 + chunk as u64);
        let x = Mat::randn(&mut rng, rows, cols);
        let path = temp_chunk_path("pf");
        write_chunked_dense(&x, chunk, &path).unwrap();
        let m = StreamedMatrix::open(&path).unwrap().with_budget(budget);
        let s = ChunkStream::start(&m).unwrap();
        (x, s, path)
    }

    #[test]
    fn delivers_chunks_in_pass_order_repeatedly() {
        let (x, s, path) = stream_over(8, 7, 3, 1 << 20);
        // Two full passes: indices cycle 0,1,2,0,1,2 with correct payloads.
        for pass in 0..2 {
            for c in 0..s.num_chunks() {
                let ch = s.next().expect("reader alive");
                assert_eq!(ch.index, c, "pass {pass}");
                assert_eq!(ch.start_col, c * 3);
                assert_eq!(ch.data.len(), 8 * ch.width);
                assert_eq!(&ch.data[..], x.col_block(ch.start_col, ch.width));
                s.recycle(ch.data);
            }
        }
        let st = s.stats();
        assert!(st.chunks_read >= 6);
        assert!(st.bytes_read >= (8 * 7 * 4) as u64 * 2);
        s.stop();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn buffer_pool_respects_budget() {
        // Budget of exactly 2 chunks -> 2 buffers (double buffering floor).
        let chunk_bytes = 8 * 3 * 4;
        let (_, s, path) = stream_over(8, 7, 3, 2 * chunk_bytes);
        assert_eq!(s.buffers(), 2);
        s.stop();
        let _ = std::fs::remove_file(path);

        // Large budget is capped.
        let (_, s, path) = stream_over(8, 7, 3, usize::MAX / 2);
        assert_eq!(s.buffers(), 64);
        s.stop();
        let _ = std::fs::remove_file(path);

        // Sub-floor budget still gets the minimum 2 buffers.
        let (_, s, path) = stream_over(8, 7, 3, 1);
        assert_eq!(s.buffers(), 2);
        s.stop();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stop_mid_pass_terminates_reader() {
        let (_, s, path) = stream_over(16, 64, 1, 1 << 20);
        let ch = s.next().unwrap();
        s.recycle(ch.data);
        s.stop(); // must not hang with 63 chunks undelivered
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn drop_without_stop_terminates_reader() {
        let (_, s, path) = stream_over(16, 64, 1, 1 << 20);
        drop(s);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn reader_error_surfaces_as_none_plus_error() {
        let (_, s, path) = stream_over(8, 6, 2, 1 << 20);
        // Truncate the file under the reader: later reads fail.
        std::fs::write(&path, b"gone").unwrap();
        let mut got_none = false;
        for _ in 0..200 {
            match s.next() {
                Some(ch) => s.recycle(ch.data), // buffered pre-truncation reads
                None => {
                    got_none = true;
                    break;
                }
            }
        }
        assert!(got_none, "reader should stop after the file vanished");
        assert!(s.take_error().is_some());
        s.stop();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stall_counter_moves_when_consumer_outruns_reader() {
        let (_, s, path) = stream_over(4, 2, 1, 1 << 20);
        // The very first next() almost always beats the reader; stalls is
        // monotone and recorded.
        let before = s.stats().buffer_stalls;
        if let Some(ch) = s.next() {
            s.recycle(ch.data);
        }
        assert!(s.stats().buffer_stalls >= before);
        s.stop();
        let _ = std::fs::remove_file(path);
    }
}
