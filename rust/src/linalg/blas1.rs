//! BLAS-1 kernels: the coordinate-descent hot path.
//!
//! Algorithm 1's inner step is exactly one [`dot`] and one [`axpy`] of
//! length *obs*, so these two functions dominate the whole solver's
//! runtime. They are written with 8-way unrolled independent accumulators,
//! which LLVM auto-vectorizes to AVX2 on the bench machine (verified in
//! EXPERIMENTS.md §Perf).

/// Dot product <x, y> with f32 accumulation over 8 independent lanes.
///
/// Independent partial sums both enable vectorization (no sequential FP
/// dependency) and reduce rounding error vs. a naive left fold.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    // Slicing to 8*chunks lets the compiler drop bounds checks in the loop.
    let (xh, xt) = x.split_at(chunks * 8);
    let (yh, yt) = y.split_at(chunks * 8);
    for (xc, yc) in xh.chunks_exact(8).zip(yh.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] = xc[k].mul_add(yc[k], acc[k]);
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (a, b) in xt.iter().zip(yt) {
        s = a.mul_add(*b, s);
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let (xh, xt) = x.split_at(chunks * 8);
    let (yh, yt) = y.split_at_mut(chunks * 8);
    for (xc, yc) in xh.chunks_exact(8).zip(yh.chunks_exact_mut(8)) {
        for k in 0..8 {
            yc[k] = xc[k].mul_add(alpha, yc[k]);
        }
    }
    for (a, b) in xt.iter().zip(yt.iter_mut()) {
        *b = a.mul_add(alpha, *b);
    }
}

/// Fused CD step: given column x and residual e, returns
/// `da = <x, e> * cninv` and applies `e -= da * x` in ONE pass over memory.
///
/// This halves the memory traffic of the Algorithm-1 inner step vs. the
/// dot-then-axpy formulation... except da depends on the full dot, so the
/// fusion is actually dot-first, then axpy — what we fuse is the *block*
/// version used by SolveBakP: see `blas2::block_update`.
#[inline]
pub fn cd_step(x: &[f32], e: &mut [f32], cninv: f32) -> f32 {
    let da = dot(x, e) * cninv;
    axpy(-da, x, e);
    da
}

/// Squared L2 norm.
#[inline]
pub fn nrm2_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn nrm2(x: &[f32]) -> f32 {
    nrm2_sq(x).sqrt()
}

/// x *= alpha.
#[inline]
pub fn scal(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Strided-source dot product: `sum(x[k*stride] * y[k])` for k in
/// 0..y.len().
///
/// This is the row-action kernel for the col-major [`crate::linalg::Mat`]:
/// row i is `&data[i..]` with stride = rows. The x accesses are indexed
/// (bounds-checked) but the lane structure removes the sequential FP
/// dependency; the cache-hostility of the strided access itself is
/// inherent to the layout — see Kaczmarz in `solver::variants`.
#[inline]
pub fn dot_strided(x: &[f32], stride: usize, y: &[f32]) -> f32 {
    debug_assert!(stride >= 1);
    debug_assert!(y.is_empty() || x.len() > (y.len() - 1) * stride);
    // 4 independent accumulator lanes break the FP dependency chain, as
    // in `dot`; the gather itself cannot vectorize across a stride.
    let mut acc = [0.0f32; 4];
    let chunks = y.len() / 4;
    for c in 0..chunks {
        let base = c * 4;
        for k in 0..4 {
            acc[k] = x[(base + k) * stride].mul_add(y[base + k], acc[k]);
        }
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for k in chunks * 4..y.len() {
        s = x[k * stride].mul_add(y[k], s);
    }
    s
}

/// Strided-source axpy: `y[k] += alpha * x[k*stride]` for k in 0..y.len().
#[inline]
pub fn axpy_strided(alpha: f32, x: &[f32], stride: usize, y: &mut [f32]) {
    debug_assert!(stride >= 1);
    debug_assert!(y.is_empty() || x.len() > (y.len() - 1) * stride);
    for (xv, yv) in x.iter().step_by(stride).zip(y.iter_mut()) {
        *yv = xv.mul_add(alpha, *yv);
    }
}

/// Sum of squares in f64 (residual tracking without f32 cancellation).
#[inline]
pub fn sum_sq_f64(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    let (h, t) = x.split_at(chunks * 4);
    for c in h.chunks_exact(4) {
        for k in 0..4 {
            acc[k] += (c[k] as f64) * (c[k] as f64);
        }
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for &v in t {
        s += (v as f64) * (v as f64);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::seed(seed);
        (0..n).map(|_| r.normal_f32()).collect()
    }

    fn naive_dot(x: &[f32], y: &[f32]) -> f64 {
        x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
    }

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_matches_naive_various_lengths() {
        for n in [1, 2, 7, 8, 9, 15, 16, 17, 63, 64, 100, 1023] {
            let x = randvec(n as u64, n);
            let y = randvec(n as u64 + 1, n);
            let got = dot(&x, &y) as f64;
            let want = naive_dot(&x, &y);
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "n={n} got={got} want={want}"
            );
        }
    }

    #[test]
    fn axpy_known() {
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn axpy_matches_naive_various_lengths() {
        for n in [1, 3, 8, 9, 31, 64, 257] {
            let x = randvec(n as u64 * 3, n);
            let mut y = randvec(n as u64 * 7, n);
            let y0 = y.clone();
            axpy(-0.5, &x, &mut y);
            for i in 0..n {
                let want = y0[i] - 0.5 * x[i];
                assert!((y[i] - want).abs() < 1e-5, "i={i}");
            }
        }
    }

    #[test]
    fn cd_step_reduces_residual() {
        let x = randvec(1, 100);
        let mut e = randvec(2, 100);
        let before = sum_sq_f64(&e);
        let cninv = 1.0 / nrm2_sq(&x);
        let da = cd_step(&x, &mut e, cninv);
        let after = sum_sq_f64(&e);
        assert!(after <= before + 1e-6);
        // e is now orthogonal to x (the Section-4 argument).
        assert!(dot(&x, &e).abs() < 1e-3, "residual not orthogonal");
        assert!(da.is_finite());
    }

    #[test]
    fn nrm2_pythagoras() {
        assert_eq!(nrm2(&[3.0, 4.0]), 5.0);
        assert_eq!(nrm2_sq(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn scal_scales() {
        let mut x = vec![1.0, -2.0, 0.5];
        scal(-2.0, &mut x);
        assert_eq!(x, vec![-2.0, 4.0, -1.0]);
    }

    #[test]
    fn strided_kernels_match_row_gather() {
        // A col-major 7x5 "matrix" flattened: element (i, j) at i + j*7.
        let rows = 7usize;
        let cols = 5usize;
        let data = randvec(77, rows * cols);
        let a = randvec(78, cols);
        for i in 0..rows {
            let row: Vec<f32> = (0..cols).map(|j| data[i + j * rows]).collect();
            let want = dot(&row, &a);
            let got = dot_strided(&data[i..], rows, &a);
            assert!((got - want).abs() < 1e-5, "row {i}: {got} vs {want}");

            let mut acc_want = a.clone();
            axpy(0.37, &row, &mut acc_want);
            let mut acc_got = a.clone();
            axpy_strided(0.37, &data[i..], rows, &mut acc_got);
            for (g, w) in acc_got.iter().zip(&acc_want) {
                assert!((g - w).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn strided_kernels_stride_one_match_contiguous() {
        let x = randvec(80, 33);
        let y = randvec(81, 33);
        assert!((dot_strided(&x, 1, &y) - dot(&x, &y)).abs() < 1e-4);
        let mut y1 = y.clone();
        let mut y2 = y.clone();
        axpy(-1.25, &x, &mut y1);
        axpy_strided(-1.25, &x, 1, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn strided_kernels_empty_dense_side() {
        assert_eq!(dot_strided(&[1.0, 2.0], 2, &[]), 0.0);
        let mut empty: Vec<f32> = vec![];
        axpy_strided(1.0, &[1.0, 2.0], 2, &mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn sum_sq_f64_matches() {
        for n in [0, 1, 5, 64, 129] {
            let x = randvec(n as u64 + 11, n);
            let want: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((sum_sq_f64(&x) - want).abs() < 1e-9 * (1.0 + want));
        }
    }
}
