//! Dense linear-algebra substrate.
//!
//! The paper's solvers are *column-action* methods: the hot loop touches one
//! column of `x` at a time ([`Mat`] is therefore **column-major**, so
//! [`Mat::col`] is a contiguous slice), plus BLAS-1/2/3 kernels tuned for
//! that access pattern ([`blas1`], [`blas2`], [`blas3`]).

pub mod blas1;
pub mod blas2;
pub mod blas3;

pub use blas1::{axpy, dot, nrm2, nrm2_sq, scal};
pub use blas2::{gemv, gemv_t};
pub use blas3::gemm_tn;

use crate::util::rng::Rng;

/// Dense column-major f32 matrix: `rows` = obs, `cols` = vars.
///
/// Column-major is the right layout for coordinate-action solvers: the
/// Algorithm-1 inner step reads exactly one column, which here is one
/// contiguous cache-friendly slice.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    /// len == rows * cols; element (i, j) at data[j * rows + i].
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// iid standard-normal entries (the paper's dense benchmark workload).
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        let mut data = vec![0.0f32; rows * cols];
        rng.fill_normal(&mut data);
        Self { rows, cols, data }
    }

    /// From column-major raw data (len must equal rows*cols).
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "bad data length");
        Self { rows, cols, data }
    }

    /// From row-major raw data (transposing copy).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "bad data length");
        Self::from_fn(rows, cols, |i, j| data[i * cols + j])
    }

    /// From a list of rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        assert!(r > 0, "empty matrix");
        let c = rows[0].len();
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Self::from_fn(r, c, |i, j| rows[i][j])
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    /// Mutable element access.
    #[inline]
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        *self.get_mut(i, j) = v;
    }

    /// Column j as a contiguous slice — the coordinate-action hot path.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f32] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Contiguous block of columns [j0, j0+width).
    #[inline]
    pub fn col_block(&self, j0: usize, width: usize) -> &[f32] {
        debug_assert!(j0 + width <= self.cols);
        &self.data[j0 * self.rows..(j0 + width) * self.rows]
    }

    /// Full column-major backing slice.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Row i as a fresh vector (strided gather; not the hot path).
    pub fn row(&self, i: usize) -> Vec<f32> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    /// Sub-matrix with the given columns (gathered copy).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for (k, &j) in idx.iter().enumerate() {
            out.col_mut(k).copy_from_slice(self.col(j));
        }
        out
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// y = X a (delegates to the threaded gemv).
    pub fn matvec(&self, a: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.cols, "matvec dim mismatch");
        blas2::gemv(self, a)
    }

    /// out = Xᵀ v.
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows, "matvec_t dim mismatch");
        blas2::gemv_t(self, v)
    }

    /// <x_j, x_j> for every column.
    pub fn colnorms_sq(&self) -> Vec<f32> {
        (0..self.cols).map(|j| blas1::nrm2_sq(self.col(j))).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Approximate memory footprint in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Residual e = y - X a, computed into a fresh vector.
pub fn residual(x: &Mat, y: &[f32], a: &[f32]) -> Vec<f32> {
    let xa = x.matvec(a);
    y.iter().zip(&xa).map(|(&yi, &xi)| yi - xi).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mat {
        // [[1, 2], [3, 4], [5, 6]]
        Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]])
    }

    #[test]
    fn construction_and_access() {
        let m = small();
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(2, 1), 6.0);
        assert_eq!(m.col(0), &[1.0, 3.0, 5.0]);
        assert_eq!(m.col(1), &[2.0, 4.0, 6.0]);
        assert_eq!(m.row(1), vec![3.0, 4.0]);
    }

    #[test]
    fn col_major_layout() {
        let m = small();
        assert_eq!(m.as_slice(), &[1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn from_row_major_matches_from_rows() {
        let m1 = small();
        let m2 = Mat::from_row_major(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m1, m2);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut m = Mat::zeros(4, 4);
        m.set(2, 3, 7.5);
        assert_eq!(m.get(2, 3), 7.5);
        assert_eq!(m.get(3, 2), 0.0);
    }

    #[test]
    fn matvec_known() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.matvec(&[2.0, -1.0]), vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn matvec_t_known() {
        let m = small();
        assert_eq!(m.matvec_t(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn colnorms() {
        let m = small();
        let n = m.colnorms_sq();
        assert_eq!(n, vec![1.0 + 9.0 + 25.0, 4.0 + 16.0 + 36.0]);
    }

    #[test]
    fn select_cols_gathers() {
        let m = small();
        let s = m.select_cols(&[1, 0]);
        assert_eq!(s.col(0), m.col(1));
        assert_eq!(s.col(1), m.col(0));
    }

    #[test]
    fn transpose_roundtrip() {
        let m = small();
        assert_eq!(m.transposed().transposed(), m);
        assert_eq!(m.transposed().get(1, 2), m.get(2, 1));
    }

    #[test]
    fn residual_zero_for_exact() {
        let m = small();
        let a = [0.5, -0.25];
        let y = m.matvec(&a);
        let e = residual(&m, &y, &a);
        assert!(e.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn randn_deterministic_and_normalish() {
        let mut r1 = Rng::seed(5);
        let mut r2 = Rng::seed(5);
        let a = Mat::randn(&mut r1, 50, 20);
        let b = Mat::randn(&mut r2, 50, 20);
        assert_eq!(a, b);
        let mean: f64 = a.as_slice().iter().map(|&v| v as f64).sum::<f64>() / 1000.0;
        assert!(mean.abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn col_block_spans_columns() {
        let m = small();
        assert_eq!(m.col_block(0, 2), m.as_slice());
        assert_eq!(m.col_block(1, 1), m.col(1));
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        let _ = Mat::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    #[should_panic]
    fn matvec_dim_mismatch_panics() {
        let _ = small().matvec(&[1.0]);
    }
}
