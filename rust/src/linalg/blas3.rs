//! BLAS-3: the Gram-matrix product `Xᵀ X` and general `Aᵀ B` needed by the
//! normal-equations baseline and the SolveBakF least-squares refits.
//!
//! Blocked over columns so both operand panels stay in cache; parallel over
//! output column strips.

use super::blas1::dot;
use super::blas2::num_threads;
use super::Mat;

/// C = Aᵀ B, where A is (m, ka) and B is (m, kb); C is (ka, kb).
///
/// Column-major makes every C entry a contiguous-slice dot product.
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn inner dim mismatch");
    let (ka, kb) = (a.cols(), b.cols());
    let mut c = Mat::zeros(ka, kb);
    let work = a.rows() * ka * kb;
    let nt = if work < 2_000_000 { 1 } else { num_threads() };
    if nt <= 1 {
        for j in 0..kb {
            let bj = b.col(j);
            let cj = c.col_mut(j);
            for (i, ci) in cj.iter_mut().enumerate() {
                *ci = dot(a.col(i), bj);
            }
        }
        return c;
    }
    // Parallel over output columns; each thread fills disjoint columns of C.
    let rows = ka;
    let data = c_data_mut(&mut c);
    let chunk = kb.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, cc) in data.chunks_mut(chunk * rows).enumerate() {
            let j0 = t * chunk;
            s.spawn(move || {
                for (local_j, col) in cc.chunks_mut(rows).enumerate() {
                    let bj = b.col(j0 + local_j);
                    for (i, ci) in col.iter_mut().enumerate() {
                        *ci = dot(a.col(i), bj);
                    }
                }
            });
        }
    });
    c
}

/// Gram matrix G = Xᵀ X (symmetric; computed full for simplicity of the
/// downstream Cholesky).
pub fn gram(x: &Mat) -> Mat {
    gemm_tn(x, x)
}

fn c_data_mut(c: &mut Mat) -> &mut [f32] {
    let rows = c.rows();
    let cols = c.cols();
    // Mat has no public data_mut; reconstruct via col_mut stitching is
    // impossible across columns, so expose through a raw slice: the backing
    // vec is contiguous col-major.
    unsafe {
        std::slice::from_raw_parts_mut(c.col_mut(0).as_mut_ptr(), rows * cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemm_tn(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.cols(), b.cols(), |i, j| {
            (0..a.rows()).map(|r| a.get(r, i) as f64 * b.get(r, j) as f64).sum::<f64>() as f32
        })
    }

    #[test]
    fn gemm_tn_known() {
        let a = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let b = Mat::from_rows(&[vec![3.0], vec![4.0]]);
        let c = gemm_tn(&a, &b);
        assert_eq!(c.shape(), (2, 1));
        assert_eq!(c.get(0, 0), 3.0);
        assert_eq!(c.get(1, 0), 8.0);
    }

    #[test]
    fn gemm_tn_matches_naive() {
        let mut rng = Rng::seed(10);
        for (m, ka, kb) in [(7, 3, 5), (64, 16, 16), (130, 20, 9)] {
            let a = Mat::randn(&mut rng, m, ka);
            let b = Mat::randn(&mut rng, m, kb);
            let got = gemm_tn(&a, &b);
            let want = naive_gemm_tn(&a, &b);
            for i in 0..ka {
                for j in 0..kb {
                    assert!((got.get(i, j) - want.get(i, j)).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn gemm_tn_threaded_path_matches() {
        let mut rng = Rng::seed(11);
        let a = Mat::randn(&mut rng, 300, 90);
        let b = Mat::randn(&mut rng, 300, 80);
        let got = gemm_tn(&a, &b);
        let want = naive_gemm_tn(&a, &b);
        for i in 0..90 {
            for j in 0..80 {
                let w = want.get(i, j);
                assert!((got.get(i, j) - w).abs() < 2e-2 * (1.0 + w.abs()));
            }
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diagonal() {
        let mut rng = Rng::seed(12);
        let x = Mat::randn(&mut rng, 50, 10);
        let g = gram(&x);
        for i in 0..10 {
            assert!(g.get(i, i) > 0.0, "diagonal positive");
            for j in 0..10 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-3, "symmetry");
            }
        }
        // Diagonal equals column norms.
        let cn = x.colnorms_sq();
        for i in 0..10 {
            assert!((g.get(i, i) - cn[i]).abs() < 1e-3);
        }
    }
}
