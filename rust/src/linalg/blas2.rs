//! BLAS-2 kernels over the column-major [`Mat`]: matrix-vector products and
//! the fused SolveBakP block update, with multi-threaded variants used by
//! the baselines (the paper's BLAS comparator runs 6-16 threads).

use super::blas1::{axpy, dot};
use super::Mat;

/// Number of worker threads for the threaded kernels: min(cores, 16),
/// matching the paper's BLAS thread counts. Overridable via
/// `SOLVEBAK_THREADS`.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("SOLVEBAK_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get().min(16))
            .unwrap_or(1)
    })
}

/// y = X a. Column-major: accumulate a_j * col_j (axpy per column).
pub fn gemv(x: &Mat, a: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), x.cols());
    let mut y = vec![0.0f32; x.rows()];
    gemv_into(x, a, &mut y);
    y
}

/// y = X a into a caller-provided buffer (zeroed here).
pub fn gemv_into(x: &Mat, a: &[f32], y: &mut [f32]) {
    assert_eq!(a.len(), x.cols());
    assert_eq!(y.len(), x.rows());
    y.fill(0.0);
    // For tall matrices parallelise over row chunks; each thread owns a
    // disjoint slice of y and walks all columns.
    let nt = effective_threads(x.rows() * x.cols());
    if nt <= 1 || x.rows() < 1024 {
        for j in 0..x.cols() {
            if a[j] != 0.0 {
                axpy(a[j], x.col(j), y);
            }
        }
        return;
    }
    let rows = x.rows();
    let chunk = rows.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, yc) in y.chunks_mut(chunk).enumerate() {
            let r0 = t * chunk;
            let len = yc.len();
            s.spawn(move || {
                for j in 0..x.cols() {
                    let aj = a[j];
                    if aj != 0.0 {
                        axpy(aj, &x.col(j)[r0..r0 + len], yc);
                    }
                }
            });
        }
    });
}

/// out = Xᵀ v (one dot per column; embarrassingly parallel over columns).
pub fn gemv_t(x: &Mat, v: &[f32]) -> Vec<f32> {
    assert_eq!(v.len(), x.rows());
    let mut out = vec![0.0f32; x.cols()];
    gemv_t_into(x, v, &mut out);
    out
}

/// out = Xᵀ v into a caller buffer.
pub fn gemv_t_into(x: &Mat, v: &[f32], out: &mut [f32]) {
    assert_eq!(v.len(), x.rows());
    assert_eq!(out.len(), x.cols());
    let nt = effective_threads(x.rows() * x.cols());
    if nt <= 1 || x.cols() < 2 * nt {
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(x.col(j), v);
        }
        return;
    }
    let chunk = x.cols().div_ceil(nt);
    std::thread::scope(|s| {
        for (t, oc) in out.chunks_mut(chunk).enumerate() {
            let j0 = t * chunk;
            s.spawn(move || {
                for (k, o) in oc.iter_mut().enumerate() {
                    *o = dot(x.col(j0 + k), v);
                }
            });
        }
    });
}

/// Fused SolveBakP block update (Algorithm 2 lines 6-9) over columns
/// [j0, j0+width):
///
///   da_k = <x_k, e> * cninv_k   for all k against the SAME stale e
///   e   -= sum_k x_k da_k
///   a_k += da_k
///
/// Single-threaded version; `solver::bakp` parallelises the da loop.
pub fn block_update(
    x: &Mat,
    j0: usize,
    width: usize,
    cninv: &[f32],
    a: &mut [f32],
    e: &mut [f32],
) {
    debug_assert!(j0 + width <= x.cols());
    // Stale-error dots.
    let mut da = [0.0f32; 64];
    let use_stack = width <= 64;
    let mut da_heap;
    let da: &mut [f32] = if use_stack {
        &mut da[..width]
    } else {
        da_heap = vec![0.0f32; width];
        &mut da_heap
    };
    for k in 0..width {
        da[k] = dot(x.col(j0 + k), e) * cninv[j0 + k];
    }
    // Error refresh + coefficient update.
    for k in 0..width {
        if da[k] != 0.0 {
            axpy(-da[k], x.col(j0 + k), e);
        }
        a[j0 + k] += da[k];
    }
}

fn effective_threads(work: usize) -> usize {
    // Heuristic: threading pays off past ~1e6 f32 ops.
    if work < 1_000_000 {
        1
    } else {
        num_threads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemv(x: &Mat, a: &[f32]) -> Vec<f32> {
        (0..x.rows())
            .map(|i| (0..x.cols()).map(|j| x.get(i, j) as f64 * a[j] as f64).sum::<f64>() as f32)
            .collect()
    }

    #[test]
    fn gemv_small_known() {
        let x = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(gemv(&x, &[1.0, 0.0]), vec![1.0, 3.0]);
        assert_eq!(gemv(&x, &[0.0, 1.0]), vec![2.0, 4.0]);
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::seed(3);
        for (r, c) in [(5, 3), (64, 64), (200, 17), (1025, 33)] {
            let x = Mat::randn(&mut rng, r, c);
            let a: Vec<f32> = (0..c).map(|_| rng.normal_f32()).collect();
            let got = gemv(&x, &a);
            let want = naive_gemv(&x, &a);
            for i in 0..r {
                assert!((got[i] - want[i]).abs() < 1e-3, "({r},{c}) i={i}");
            }
        }
    }

    #[test]
    fn gemv_threaded_path_matches() {
        // Force the threaded branch: rows >= 1024 and work >= 1e6.
        let mut rng = Rng::seed(4);
        let x = Mat::randn(&mut rng, 2048, 600);
        let a: Vec<f32> = (0..600).map(|_| rng.normal_f32()).collect();
        let got = gemv(&x, &a);
        let want = naive_gemv(&x, &a);
        for i in 0..2048 {
            assert!((got[i] - want[i]).abs() < 2e-2 * (1.0 + want[i].abs()), "i={i}");
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let mut rng = Rng::seed(5);
        let x = Mat::randn(&mut rng, 40, 30);
        let v: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let got = gemv_t(&x, &v);
        let want = gemv(&x.transposed(), &v);
        for j in 0..30 {
            assert!((got[j] - want[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn gemv_t_threaded_path_matches() {
        let mut rng = Rng::seed(6);
        let x = Mat::randn(&mut rng, 4096, 333);
        let v: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let got = gemv_t(&x, &v);
        let xt = x.transposed();
        let want = naive_gemv(&xt, &v);
        for j in 0..333 {
            assert!((got[j] - want[j]).abs() < 5e-2 * (1.0 + want[j].abs()), "j={j}");
        }
    }

    #[test]
    fn block_update_matches_scalar_semantics() {
        // width=1 block update == one sequential CD step.
        let mut rng = Rng::seed(7);
        let x = Mat::randn(&mut rng, 50, 4);
        let cn: Vec<f32> = x.colnorms_sq().iter().map(|&v| 1.0 / v).collect();
        let y: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();

        let mut a1 = vec![0.0f32; 4];
        let mut e1 = y.clone();
        block_update(&x, 2, 1, &cn, &mut a1, &mut e1);

        let mut e2 = y.clone();
        let da = crate::linalg::blas1::cd_step(x.col(2), &mut e2, cn[2]);
        assert!((a1[2] - da).abs() < 1e-6);
        for i in 0..50 {
            assert!((e1[i] - e2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn block_update_stale_semantics() {
        // All da in a block must be computed against the pre-block error.
        let mut rng = Rng::seed(8);
        let x = Mat::randn(&mut rng, 30, 3);
        let cn: Vec<f32> = x.colnorms_sq().iter().map(|&v| 1.0 / v).collect();
        let y: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
        let mut a = vec![0.0f32; 3];
        let mut e = y.clone();
        block_update(&x, 0, 3, &cn, &mut a, &mut e);
        for k in 0..3 {
            let want = dot(x.col(k), &y) * cn[k]; // stale: against y, not e'
            assert!((a[k] - want).abs() < 1e-5, "k={k}");
        }
    }

    #[test]
    fn block_update_wide_block_heap_path() {
        // width > 64 exercises the heap-allocated da path.
        let mut rng = Rng::seed(9);
        let x = Mat::randn(&mut rng, 40, 100);
        let cn: Vec<f32> = x.colnorms_sq().iter().map(|&v| 1.0 / v).collect();
        let y: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let mut a = vec![0.0f32; 100];
        let mut e = y.clone();
        block_update(&x, 0, 100, &cn, &mut a, &mut e);
        // e' must equal y - X da.
        let xa = gemv(&x, &a);
        for i in 0..40 {
            assert!((e[i] - (y[i] - xa[i])).abs() < 1e-3);
        }
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
