//! Admission control: a semaphore-style gate in front of the job queue.
//!
//! The coordinator takes a [`Permit`] before a request may enter the
//! submit queue and holds it until the reply is sent, so `max_inflight`
//! bounds *end-to-end* concurrency (queued + executing), not just pool
//! width. Saturated callers wait up to `max_queue_wait_ms`; past that the
//! service sheds the request with a structured `overloaded` error (or
//! degrades it — see [`crate::coordinator`]). Permits release on `Drop`,
//! so error and panic paths can never leak a slot.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Counting gate with a hard capacity. Construct via [`AdmissionGate::new`].
#[derive(Debug)]
pub struct AdmissionGate {
    max: usize,
    inflight: Mutex<usize>,
    freed: Condvar,
}

/// RAII admission slot; dropping it frees capacity and wakes one waiter.
#[derive(Debug)]
pub struct Permit {
    gate: Arc<AdmissionGate>,
}

impl AdmissionGate {
    /// A gate admitting at most `max` concurrent requests (`max >= 1`).
    pub fn new(max: usize) -> Arc<Self> {
        Arc::new(AdmissionGate {
            max: max.max(1),
            inflight: Mutex::new(0),
            freed: Condvar::new(),
        })
    }

    /// Immediate acquisition attempt; `None` when saturated.
    pub fn try_acquire(self: &Arc<Self>) -> Option<Permit> {
        let mut n = self.inflight.lock().expect("gate poisoned");
        if *n < self.max {
            *n += 1;
            Some(Permit { gate: Arc::clone(self) })
        } else {
            None
        }
    }

    /// Wait up to `wait` for a slot; `None` on timeout.
    pub fn acquire_timeout(self: &Arc<Self>, wait: Duration) -> Option<Permit> {
        let deadline = Instant::now() + wait;
        let mut n = self.inflight.lock().expect("gate poisoned");
        loop {
            if *n < self.max {
                *n += 1;
                return Some(Permit { gate: Arc::clone(self) });
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timeout) = self
                .freed
                .wait_timeout(n, deadline - now)
                .expect("gate poisoned");
            n = guard;
            // Loop re-checks capacity and the deadline; spurious wakeups
            // and timed-out waits both land back here.
        }
    }

    /// Currently admitted requests (queued + executing).
    pub fn inflight(&self) -> usize {
        *self.inflight.lock().expect("gate poisoned")
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.max
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut n = self.gate.inflight.lock().expect("gate poisoned");
        *n = n.saturating_sub(1);
        self.gate.freed.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_capacity_then_rejects() {
        let g = AdmissionGate::new(2);
        let p1 = g.try_acquire().expect("slot 1");
        let p2 = g.try_acquire().expect("slot 2");
        assert!(g.try_acquire().is_none(), "gate full");
        assert_eq!(g.inflight(), 2);
        drop(p1);
        assert_eq!(g.inflight(), 1);
        let p3 = g.try_acquire().expect("slot freed by drop");
        drop((p2, p3));
        assert_eq!(g.inflight(), 0);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let g = AdmissionGate::new(0);
        assert_eq!(g.capacity(), 1);
        let _p = g.try_acquire().expect("one slot");
        assert!(g.try_acquire().is_none());
    }

    #[test]
    fn acquire_timeout_times_out_when_saturated() {
        let g = AdmissionGate::new(1);
        let _held = g.try_acquire().expect("slot");
        let t0 = Instant::now();
        assert!(g.acquire_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn acquire_timeout_wakes_when_permit_drops() {
        let g = AdmissionGate::new(1);
        let held = g.try_acquire().expect("slot");
        let g2 = Arc::clone(&g);
        let waiter = std::thread::spawn(move || {
            g2.acquire_timeout(Duration::from_secs(5)).is_some()
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(held);
        assert!(waiter.join().expect("no panic"), "waiter got the freed slot");
        assert_eq!(g.inflight(), 0);
    }
}
