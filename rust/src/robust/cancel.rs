//! Cooperative cancellation tokens with optional deadlines.
//!
//! A [`CancelToken`] rides inside [`crate::solver::SolveOptions`] and is
//! polled by every iterative solver at its residual-check points — the
//! same places the convergence probe observes. The disabled default is a
//! `None` that costs a single branch per check: no clock read, no atomic
//! load, no allocation, so solves without a deadline remain bit-identical
//! to builds that predate cancellation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug)]
struct CancelInner {
    /// Absolute deadline; `None` for manually-cancelled-only tokens.
    deadline: Option<Instant>,
    /// Explicit cancellation flag (set by [`CancelToken::cancel`]).
    flag: AtomicBool,
}

/// Shared cancellation token. Cloning shares the underlying state, so a
/// coordinator can arm one token and hand clones to every stage of a job.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Option<Arc<CancelInner>>);

impl CancelToken {
    /// The disabled token: never cancels, costs one branch to poll.
    pub fn none() -> Self {
        CancelToken(None)
    }

    /// An armed token with no deadline; cancels only via [`cancel`].
    ///
    /// [`cancel`]: CancelToken::cancel
    pub fn manual() -> Self {
        CancelToken(Some(Arc::new(CancelInner {
            deadline: None,
            flag: AtomicBool::new(false),
        })))
    }

    /// A token that expires `budget` from now (or earlier via [`cancel`]).
    ///
    /// [`cancel`]: CancelToken::cancel
    pub fn with_deadline(budget: Duration) -> Self {
        CancelToken(Some(Arc::new(CancelInner {
            deadline: Some(Instant::now() + budget),
            flag: AtomicBool::new(false),
        })))
    }

    /// Millisecond shorthand for [`with_deadline`] — the wire-protocol
    /// unit (`"deadline_ms"`).
    ///
    /// [`with_deadline`]: CancelToken::with_deadline
    pub fn with_deadline_ms(ms: u64) -> Self {
        Self::with_deadline(Duration::from_millis(ms))
    }

    /// Whether this token can ever cancel (armed manually or by deadline).
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Request cancellation. No-op on a disabled token.
    pub fn cancel(&self) {
        if let Some(inner) = &self.0 {
            inner.flag.store(true, Ordering::Relaxed);
        }
    }

    /// Poll the token: `true` once cancelled or past the deadline.
    ///
    /// Hot-loop contract: one branch when disabled; one relaxed load plus
    /// at most one clock read when armed.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        match &self.0 {
            None => false,
            Some(inner) => {
                inner.flag.load(Ordering::Relaxed)
                    || inner.deadline.is_some_and(|d| Instant::now() >= d)
            }
        }
    }

    /// Milliseconds left before the deadline (`None` when no deadline is
    /// armed; `Some(0)` once expired). Used for `retry_after_ms` hints.
    pub fn remaining_ms(&self) -> Option<u64> {
        let inner = self.0.as_ref()?;
        let deadline = inner.deadline?;
        Some(deadline.saturating_duration_since(Instant::now()).as_millis() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_token_never_cancels() {
        let t = CancelToken::none();
        assert!(!t.is_enabled());
        assert!(!t.is_cancelled());
        t.cancel(); // no-op
        assert!(!t.is_cancelled());
        assert_eq!(t.remaining_ms(), None);
    }

    #[test]
    fn default_is_disabled() {
        assert!(!CancelToken::default().is_enabled());
    }

    #[test]
    fn manual_cancel_is_shared_across_clones() {
        let t = CancelToken::manual();
        let clone = t.clone();
        assert!(t.is_enabled());
        assert!(!clone.is_cancelled());
        t.cancel();
        assert!(clone.is_cancelled());
        assert_eq!(t.remaining_ms(), None); // no deadline armed
    }

    #[test]
    fn deadline_expires() {
        let t = CancelToken::with_deadline(Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.is_cancelled());
        assert_eq!(t.remaining_ms(), Some(0));
    }

    #[test]
    fn far_deadline_not_yet_cancelled() {
        let t = CancelToken::with_deadline_ms(60_000);
        assert!(t.is_enabled());
        assert!(!t.is_cancelled());
        let rem = t.remaining_ms().expect("deadline armed");
        assert!(rem > 55_000, "remaining {rem}ms");
    }
}
