//! Robustness layer: deadlines & cancellation, admission control, and
//! fault injection.
//!
//! The BAK family degrades gracefully by construction — accuracy is
//! controlled by the sweep budget — so the service can always trade
//! precision for latency instead of queueing forever. This module holds
//! the three mechanisms that exploit that:
//!
//! * [`CancelToken`] — a shared, deadline-carrying token checked at every
//!   residual probe in the iterative solvers (the PR 7 `SolveProbe` hook
//!   points). Disabled tokens cost one branch per check, mirroring
//!   [`crate::obs::ProbeHandle`]'s zero-cost contract, so deterministic
//!   solves stay bit-identical when no deadline is armed.
//! * [`AdmissionGate`] — a semaphore-style gate in front of the
//!   coordinator's job queue (`max_inflight` / `max_queue_wait_ms`).
//!   Saturation produces a structured `overloaded` reply with a
//!   `retry_after_ms` hint, or — in degraded mode — a reduced-sweep BAK
//!   answer instead of a rejection.
//! * [`FaultPlan`] — process-global fault injection (worker panics, slow
//!   chunk reads in the stream prefetcher, scheduler stalls, chunk
//!   corruption), configured from the `PALLAS_FAULTS` environment
//!   variable or the TCP `faults` command, so CI's `chaos-smoke` and
//!   `recovery-smoke` jobs can prove the mechanisms above actually hold
//!   under fire.
//!
//! The durability layer rides the same probe points:
//!
//! * [`Checkpoint`] / [`checkpoint::CheckpointProbe`] — versioned,
//!   CRC-sealed `.ckpt` snapshots written atomically every N sweeps, so a
//!   killed solve resumes bit-identically via
//!   [`crate::api::Problem::with_warm_state`].
//! * [`Watchdog`] — numerical-health monitoring (NaN/Inf, divergence,
//!   stagnation) that aborts through a [`CancelToken`] and yields a
//!   [`watchdog::Verdict`] the coordinator maps to
//!   `numerical_breakdown` — or, with `"escalate": true`, to a retry on
//!   the next backend up the ladder.

pub mod cancel;
pub mod checkpoint;
pub mod faults;
pub mod gate;
pub mod watchdog;

pub use cancel::CancelToken;
pub use checkpoint::{Checkpoint, CheckpointProbe};
pub use faults::FaultPlan;
pub use gate::{AdmissionGate, Permit};
pub use watchdog::{Watchdog, WatchdogConfig};
