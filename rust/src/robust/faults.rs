//! Fault injection for chaos testing.
//!
//! A [`FaultPlan`] describes which faults to inject and how often; it is
//! installed into process-global state (env var `PALLAS_FAULTS` at
//! startup, or the TCP `faults` command at runtime) and polled from three
//! hook points:
//!
//! * [`maybe_panic_worker`] — coordinator worker, at job start: panics
//!   every Nth job so the executor's `catch_unwind` isolation and the
//!   reply path for poisoned jobs get exercised.
//! * [`slow_read_delay`] — stream prefetch reader, before each chunk
//!   read: sleeps to simulate a slow disk and force deadline expiry on
//!   streamed solves.
//! * [`queue_stall`] — coordinator scheduler loop: sleeps before
//!   dispatching a batch, backing the submit queue up so admission
//!   control has something to shed.
//! * [`corrupt_chunk`] — chunk-file reader, after each chunk read: tells
//!   the reader to flip one payload byte so the `.sbck` v2 per-chunk CRC
//!   check and the `corrupt_data` error path get exercised end-to-end.
//!
//! The disabled state (no plan, or an all-zero plan) costs one relaxed
//! atomic load per hook — faults never perturb a production solve.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// Environment variable read by [`init_from_env`].
pub const FAULTS_ENV: &str = "PALLAS_FAULTS";

/// A parsed fault-injection plan. All knobs default to 0 (= off).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic the worker on every Nth job (0 = never).
    pub worker_panic_every: u64,
    /// Sleep this long before an injected slow chunk read (0 = never).
    pub slow_read_ms: u64,
    /// Inject the slow read on every Nth chunk (0 or 1 = every chunk,
    /// when `slow_read_ms` > 0).
    pub slow_read_every: u64,
    /// Sleep this long in the scheduler before each dispatch (0 = never).
    pub queue_stall_ms: u64,
    /// Flip one byte in every Nth chunk read from a `.sbck` file
    /// (0 = never). Only v2 files detect the flip — that is the point of
    /// the knob.
    pub corrupt_chunk_every: u64,
}

impl FaultPlan {
    /// Parse a comma-separated `key=value` spec, e.g.
    /// `"worker_panic_every=7,slow_read_ms=50,slow_read_every=3"`.
    /// The empty string parses to the all-off plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec '{part}' is not key=value"))?;
            let n: u64 = val
                .trim()
                .parse()
                .map_err(|_| format!("fault knob '{}': bad integer '{}'", key.trim(), val.trim()))?;
            match key.trim() {
                "worker_panic_every" => plan.worker_panic_every = n,
                "slow_read_ms" => plan.slow_read_ms = n,
                "slow_read_every" => plan.slow_read_every = n,
                "queue_stall_ms" => plan.queue_stall_ms = n,
                "corrupt_chunk_every" => plan.corrupt_chunk_every = n,
                other => return Err(format!("unknown fault knob '{other}'")),
            }
        }
        Ok(plan)
    }

    /// True when no fault can ever fire.
    pub fn is_noop(&self) -> bool {
        *self == FaultPlan::default()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "worker_panic_every={},slow_read_ms={},slow_read_every={},queue_stall_ms={},corrupt_chunk_every={}",
            self.worker_panic_every,
            self.slow_read_ms,
            self.slow_read_every,
            self.queue_stall_ms,
            self.corrupt_chunk_every
        )
    }
}

/// Process-global knobs + hook-call counters. Atomics (not a locked
/// `FaultPlan`) so the hot hooks never take a lock.
struct FaultState {
    worker_panic_every: AtomicU64,
    slow_read_ms: AtomicU64,
    slow_read_every: AtomicU64,
    queue_stall_ms: AtomicU64,
    corrupt_chunk_every: AtomicU64,
    worker_calls: AtomicU64,
    read_calls: AtomicU64,
    chunk_calls: AtomicU64,
}

/// Fast-path switch: hooks bail on one relaxed load when no plan is live.
static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: OnceLock<FaultState> = OnceLock::new();

fn state() -> &'static FaultState {
    STATE.get_or_init(|| FaultState {
        worker_panic_every: AtomicU64::new(0),
        slow_read_ms: AtomicU64::new(0),
        slow_read_every: AtomicU64::new(0),
        queue_stall_ms: AtomicU64::new(0),
        corrupt_chunk_every: AtomicU64::new(0),
        worker_calls: AtomicU64::new(0),
        read_calls: AtomicU64::new(0),
        chunk_calls: AtomicU64::new(0),
    })
}

/// Install `plan` as the live process-global plan (replacing any prior
/// one). An all-off plan flips the hooks back to their one-load fast path.
pub fn install(plan: &FaultPlan) {
    let s = state();
    s.worker_panic_every.store(plan.worker_panic_every, Ordering::Relaxed);
    s.slow_read_ms.store(plan.slow_read_ms, Ordering::Relaxed);
    s.slow_read_every.store(plan.slow_read_every, Ordering::Relaxed);
    s.queue_stall_ms.store(plan.queue_stall_ms, Ordering::Relaxed);
    s.corrupt_chunk_every.store(plan.corrupt_chunk_every, Ordering::Relaxed);
    ENABLED.store(!plan.is_noop(), Ordering::Relaxed);
}

/// Disarm all faults.
pub fn clear() {
    install(&FaultPlan::default());
}

/// The live plan (all-off when nothing was installed).
pub fn current() -> FaultPlan {
    if !ENABLED.load(Ordering::Relaxed) {
        return FaultPlan::default();
    }
    let s = state();
    FaultPlan {
        worker_panic_every: s.worker_panic_every.load(Ordering::Relaxed),
        slow_read_ms: s.slow_read_ms.load(Ordering::Relaxed),
        slow_read_every: s.slow_read_every.load(Ordering::Relaxed),
        queue_stall_ms: s.queue_stall_ms.load(Ordering::Relaxed),
        corrupt_chunk_every: s.corrupt_chunk_every.load(Ordering::Relaxed),
    }
}

/// Install a plan from `PALLAS_FAULTS` if the variable is set. Called by
/// `serve-tcp` and `Coordinator::start`; a malformed spec is logged and
/// ignored rather than killing the server.
pub fn init_from_env() {
    let Ok(spec) = std::env::var(FAULTS_ENV) else {
        return;
    };
    match FaultPlan::parse(&spec) {
        Ok(plan) => {
            if !plan.is_noop() {
                crate::warn_!("faults", "fault injection armed from {FAULTS_ENV}: {plan}");
            }
            install(&plan);
        }
        Err(e) => crate::warn_!("faults", "ignoring malformed {FAULTS_ENV}: {e}"),
    }
}

/// Worker hook: panics on every Nth call when armed. The coordinator's
/// executor catches the unwind per job (`worker_panics` metric).
#[inline]
pub fn maybe_panic_worker() {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let s = state();
    let every = s.worker_panic_every.load(Ordering::Relaxed);
    if every == 0 {
        return;
    }
    let n = s.worker_calls.fetch_add(1, Ordering::Relaxed) + 1;
    if n % every == 0 {
        panic!("injected fault: worker panic (job call {n})");
    }
}

/// Prefetch-reader hook: the delay to sleep before this chunk read, if
/// the plan says this call is the unlucky Nth one.
#[inline]
pub fn slow_read_delay() -> Option<Duration> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let s = state();
    let ms = s.slow_read_ms.load(Ordering::Relaxed);
    if ms == 0 {
        return None;
    }
    let every = s.slow_read_every.load(Ordering::Relaxed).max(1);
    let n = s.read_calls.fetch_add(1, Ordering::Relaxed) + 1;
    if n % every == 0 {
        Some(Duration::from_millis(ms))
    } else {
        None
    }
}

/// Chunk-reader hook: true when this chunk read should have one payload
/// byte flipped (every Nth call when armed). The flip happens in
/// [`crate::stream::format::FileChunkSource`], after the bytes are read
/// and before the v2 CRC check, so the corruption is detected exactly
/// where real bit rot would be.
#[inline]
pub fn corrupt_chunk() -> bool {
    if !ENABLED.load(Ordering::Relaxed) {
        return false;
    }
    let s = state();
    let every = s.corrupt_chunk_every.load(Ordering::Relaxed);
    if every == 0 {
        return false;
    }
    let n = s.chunk_calls.fetch_add(1, Ordering::Relaxed) + 1;
    n % every == 0
}

/// Scheduler hook: the stall to sleep before dispatching, when armed.
#[inline]
pub fn queue_stall() -> Option<Duration> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let ms = state().queue_stall_ms.load(Ordering::Relaxed);
    if ms == 0 {
        None
    } else {
        Some(Duration::from_millis(ms))
    }
}

/// Serialises tests that touch the process-global fault state (this
/// module's hook tests and the server's `faults`-command tests share one
/// test binary and would otherwise race).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_defaults() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        let p = FaultPlan::parse(
            "worker_panic_every=7, slow_read_ms=50,slow_read_every=3,corrupt_chunk_every=4",
        )
        .unwrap();
        assert_eq!(p.worker_panic_every, 7);
        assert_eq!(p.slow_read_ms, 50);
        assert_eq!(p.slow_read_every, 3);
        assert_eq!(p.queue_stall_ms, 0);
        assert_eq!(p.corrupt_chunk_every, 4);
        assert!(!p.is_noop());
        assert_eq!(FaultPlan::parse(&p.to_string()).unwrap(), p);
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(FaultPlan::parse("worker_panic_every").is_err());
        assert!(FaultPlan::parse("worker_panic_every=abc").is_err());
        assert!(FaultPlan::parse("bogus_knob=1").is_err());
    }

    // The install/hook tests below mutate process-global state, so they
    // run as one test to avoid racing each other under the parallel test
    // harness. Every path ends with `clear()`.
    #[test]
    fn global_hooks_honour_the_installed_plan() {
        let _guard = test_guard();
        clear();
        assert!(current().is_noop());
        assert!(slow_read_delay().is_none());
        assert!(queue_stall().is_none());
        maybe_panic_worker(); // must not panic when disarmed

        install(&FaultPlan { queue_stall_ms: 5, ..FaultPlan::default() });
        assert_eq!(queue_stall(), Some(Duration::from_millis(5)));
        assert!(slow_read_delay().is_none(), "slow reads still off");
        assert_eq!(current().queue_stall_ms, 5);

        install(&FaultPlan { slow_read_ms: 9, slow_read_every: 2, ..FaultPlan::default() });
        // every=2: exactly one of two consecutive calls fires.
        let fired = [slow_read_delay(), slow_read_delay()];
        assert_eq!(fired.iter().flatten().count(), 1, "{fired:?}");
        assert_eq!(fired.iter().flatten().next(), Some(&Duration::from_millis(9)));

        install(&FaultPlan { corrupt_chunk_every: 3, ..FaultPlan::default() });
        // every=3: exactly one of three consecutive reads is corrupted.
        let hits = [corrupt_chunk(), corrupt_chunk(), corrupt_chunk()];
        assert_eq!(hits.iter().filter(|h| **h).count(), 1, "{hits:?}");

        let caught = std::panic::catch_unwind(|| {
            install(&FaultPlan { worker_panic_every: 1, ..FaultPlan::default() });
            maybe_panic_worker();
        });
        assert!(caught.is_err(), "worker panic fault fires");

        clear();
        assert!(current().is_noop());
        maybe_panic_worker(); // disarmed again
    }
}
