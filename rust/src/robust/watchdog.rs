//! Numerical-health watchdog: a [`SolveProbe`] that watches the residual
//! trajectory for NaN/Inf, sustained divergence, and (optionally)
//! stagnation, and aborts the solve through a [`CancelToken`] the moment
//! a pathology is confirmed — instead of burning the remaining sweep
//! budget iterating on garbage.
//!
//! The watchdog does not return errors itself (probes have no error
//! channel). It cancels the token it guards and records a [`Verdict`];
//! after the solve, the caller checks [`Watchdog::verdict`] to tell a
//! watchdog abort apart from a genuine deadline hit — both surface as
//! `StopReason::Cancelled` — and maps it to
//! [`SolverError::NumericalBreakdown`]. The coordinator does exactly
//! this, and with `"escalate": true` re-routes the job down the backend
//! ladder (BAK → CGLS → QR) instead of failing it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::api::SolverError;
use crate::obs::SolveProbe;
use crate::robust::CancelToken;

/// Detection thresholds. The defaults are deliberately conservative:
/// coordinate descent's residual is near-monotone, so five consecutive
/// increases that end an order of magnitude above the best seen is a
/// clear pathology, not noise.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WatchdogConfig {
    /// Consecutive residual increases before divergence is declared.
    pub divergence_patience: usize,
    /// The residual must also exceed `best * divergence_factor` for the
    /// divergence verdict to fire (filters benign plateau wiggle).
    pub divergence_factor: f64,
    /// Checks without meaningful improvement before stagnation is
    /// declared; 0 disables stagnation detection (the default — solvers
    /// already stop on their own `thr` stall counter, so this knob is for
    /// callers that disabled it).
    pub stagnation_patience: usize,
    /// Relative improvement below which a check counts as stagnant.
    pub stagnation_epsilon: f64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            divergence_patience: 5,
            divergence_factor: 10.0,
            stagnation_patience: 0,
            stagnation_epsilon: 1e-6,
        }
    }
}

/// What the watchdog concluded about the solve.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// No pathology observed.
    Healthy,
    /// The watchdog aborted the solve.
    Breakdown {
        /// Human-readable reason ("residual is NaN", "diverging: …").
        detail: String,
        /// Sweep count at the abort.
        sweeps: usize,
    },
}

impl Verdict {
    /// The typed error for a breakdown verdict (None when healthy).
    pub fn to_error(&self) -> Option<SolverError> {
        match self {
            Verdict::Healthy => None,
            Verdict::Breakdown { detail, sweeps } => Some(SolverError::NumericalBreakdown {
                detail: detail.clone(),
                sweeps: *sweeps,
            }),
        }
    }
}

struct WdState {
    best: f64,
    prev: f64,
    rising: usize,
    stagnant: usize,
    verdict: Verdict,
}

/// The watchdog probe. Attach via [`Watchdog::probe`] (alone or inside a
/// [`crate::obs::MultiProbe`]) and put [`Watchdog::cancel_token`] into
/// [`crate::solver::SolveOptions::cancel`]; after the solve, check
/// [`Watchdog::verdict`].
pub struct Watchdog {
    cfg: WatchdogConfig,
    cancel: CancelToken,
    tripped: AtomicBool,
    state: Mutex<WdState>,
}

impl Watchdog {
    /// A watchdog guarding a fresh manual [`CancelToken`].
    pub fn new(cfg: WatchdogConfig) -> Arc<Self> {
        Self::guarding(cfg, CancelToken::manual())
    }

    /// A watchdog that cancels an existing armed token — use this when
    /// the job already carries a deadline token, so one token serves
    /// both; [`Watchdog::tripped`] disambiguates afterwards.
    pub fn guarding(cfg: WatchdogConfig, cancel: CancelToken) -> Arc<Self> {
        Arc::new(Watchdog {
            cfg,
            cancel,
            tripped: AtomicBool::new(false),
            state: Mutex::new(WdState {
                best: f64::INFINITY,
                prev: f64::INFINITY,
                rising: 0,
                stagnant: 0,
                verdict: Verdict::Healthy,
            }),
        })
    }

    /// The token this watchdog cancels on breakdown (clone it into
    /// [`crate::solver::SolveOptions::cancel`]).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// This watchdog as a probe member.
    pub fn probe(self: &Arc<Self>) -> Arc<dyn SolveProbe> {
        self.clone()
    }

    /// True once the watchdog aborted the solve. Check this before
    /// attributing a `StopReason::Cancelled` to the deadline.
    pub fn tripped(&self) -> bool {
        self.tripped.load(Ordering::Relaxed)
    }

    /// The verdict so far.
    pub fn verdict(&self) -> Verdict {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .verdict
            .clone()
    }

    fn trip(&self, g: &mut WdState, detail: String, sweeps: usize) {
        g.verdict = Verdict::Breakdown { detail, sweeps };
        self.tripped.store(true, Ordering::Relaxed);
        self.cancel.cancel();
    }
}

impl SolveProbe for Watchdog {
    fn on_sweep(&self, sweep: usize, residual_norm: f64, _elapsed_ns: u64) {
        if self.tripped.load(Ordering::Relaxed) {
            return;
        }
        let mut g = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if !residual_norm.is_finite() {
            self.trip(&mut g, "residual is NaN/Inf".into(), sweep);
            return;
        }
        if residual_norm > g.prev {
            g.rising += 1;
            if g.rising >= self.cfg.divergence_patience
                && residual_norm > g.best * self.cfg.divergence_factor
            {
                let detail = format!(
                    "diverging: residual {residual_norm:.3e} rose {} checks in a row \
                     ({}x the best seen {:.3e})",
                    g.rising,
                    self.cfg.divergence_factor,
                    g.best
                );
                self.trip(&mut g, detail, sweep);
                return;
            }
        } else {
            g.rising = 0;
        }
        if self.cfg.stagnation_patience > 0 {
            if residual_norm > g.best * (1.0 - self.cfg.stagnation_epsilon) {
                g.stagnant += 1;
                if g.stagnant >= self.cfg.stagnation_patience {
                    let detail = format!(
                        "stagnating: no {:.1e} relative improvement in {} checks \
                         (best {:.3e})",
                        self.cfg.stagnation_epsilon, g.stagnant, g.best
                    );
                    self.trip(&mut g, detail, sweep);
                    return;
                }
            } else {
                g.stagnant = 0;
            }
        }
        g.prev = residual_norm;
        g.best = g.best.min(residual_norm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_convergence_never_trips() {
        let wd = Watchdog::new(WatchdogConfig::default());
        for k in 1..=100usize {
            wd.on_sweep(k, 1.0 / k as f64, 0);
        }
        assert!(!wd.tripped());
        assert_eq!(wd.verdict(), Verdict::Healthy);
        assert!(!wd.cancel_token().is_cancelled());
        assert!(wd.verdict().to_error().is_none());
    }

    #[test]
    fn nan_residual_trips_immediately() {
        let wd = Watchdog::new(WatchdogConfig::default());
        wd.on_sweep(1, 4.0, 0);
        wd.on_sweep(2, f64::NAN, 0);
        assert!(wd.tripped());
        assert!(wd.cancel_token().is_cancelled());
        match wd.verdict() {
            Verdict::Breakdown { detail, sweeps } => {
                assert_eq!(sweeps, 2);
                assert!(detail.contains("NaN"), "{detail}");
            }
            v => panic!("expected breakdown, got {v:?}"),
        }
        // Verdict is sticky: later healthy observations don't erase it.
        wd.on_sweep(3, 0.1, 0);
        assert!(wd.tripped());
    }

    #[test]
    fn sustained_divergence_trips_but_wiggle_does_not() {
        let cfg = WatchdogConfig::default();
        // Benign wiggle: rises never sustained for `patience` checks.
        let wd = Watchdog::new(cfg);
        for k in 1..=50usize {
            let base = 1.0 / k as f64;
            wd.on_sweep(k, if k % 3 == 0 { base * 1.5 } else { base }, 0);
        }
        assert!(!wd.tripped(), "wiggle misdiagnosed as divergence");

        // Geometric blow-up: trips once patience and factor are both met.
        let wd = Watchdog::new(cfg);
        wd.on_sweep(1, 1.0, 0);
        let mut r = 1.0;
        let mut tripped_at = None;
        for k in 2..=20usize {
            r *= 2.0;
            wd.on_sweep(k, r, 0);
            if wd.tripped() {
                tripped_at = Some(k);
                break;
            }
        }
        let at = tripped_at.expect("divergence never tripped");
        assert!(at >= 1 + cfg.divergence_patience, "tripped too eagerly at {at}");
        let err = wd.verdict().to_error().expect("breakdown error");
        assert!(matches!(err, SolverError::NumericalBreakdown { .. }), "{err}");
    }

    #[test]
    fn stagnation_is_opt_in() {
        // Default config: a flat residual forever never trips.
        let wd = Watchdog::new(WatchdogConfig::default());
        for k in 1..=200usize {
            wd.on_sweep(k, 0.5, 0);
        }
        assert!(!wd.tripped());

        // Opted in: a flat residual trips after the patience window.
        let wd = Watchdog::new(WatchdogConfig {
            stagnation_patience: 10,
            ..WatchdogConfig::default()
        });
        for k in 1..=200usize {
            wd.on_sweep(k, 0.5, 0);
            if wd.tripped() {
                break;
            }
        }
        assert!(wd.tripped());
        match wd.verdict() {
            Verdict::Breakdown { detail, .. } => {
                assert!(detail.contains("stagnating"), "{detail}")
            }
            v => panic!("expected breakdown, got {v:?}"),
        }
    }

    #[test]
    fn guarding_shares_the_callers_token() {
        let token = CancelToken::manual();
        let wd = Watchdog::guarding(WatchdogConfig::default(), token.clone());
        wd.on_sweep(1, f64::INFINITY, 0);
        assert!(token.is_cancelled(), "caller's token not cancelled");
        assert!(wd.tripped());
    }
}
