//! Durable solver checkpoints: a versioned on-disk snapshot of an
//! iterative solve's resumable state, written atomically from the probe
//! hook so a killed process can warm-start instead of recomputing.
//!
//! Two pieces:
//!
//! * [`Checkpoint`] — the snapshot itself: job id, solver kind, sweep
//!   count, seed, the iterate `a` AND the maintained residual `e`, sealed
//!   with a CRC32 trailer. Storing `e` (instead of recomputing `y - Xa`
//!   on resume) is what makes a resumed solve bit-identical to an
//!   uninterrupted one: the incrementally-updated residual drifts from
//!   the from-scratch product by accumulated f32 rounding, so a
//!   recomputed residual would fork the trajectory.
//! * [`CheckpointProbe`] — a [`SolveProbe`] that persists a [`Checkpoint`]
//!   every `every` sweeps via the opt-in `on_state` hook. Writes are
//!   atomic (temp file + rename), so a crash mid-write leaves the
//!   previous checkpoint intact, and write failures are recorded but
//!   never abort the solve — a full disk must not kill a converging job.
//!
//! ## File format (`.ckpt`, version 1, little-endian)
//!
//! ```text
//! offset  size          field
//! 0       4             magic "PCKP"
//! 4       1             format version (1)
//! 5       2             job id length (u16)
//! 7       j             job id bytes (UTF-8)
//! 7+j     1             solver kind length (u8)
//! 8+j     k             solver kind bytes (UTF-8, SolverKind::as_str)
//! ...     8             sweeps completed (u64)
//! ...     8             solve seed (u64)
//! ...     8             vars = len(a) (u64)
//! ...     8             obs  = len(e) (u64)
//! ...     vars*4        a, f32 little-endian
//! ...     obs*4         e, f32 little-endian
//! ...     4             CRC32 (IEEE) of every preceding byte
//! ```
//!
//! The version byte follows the same policy as `.sbck` (see
//! CONTRIBUTING.md): readers reject versions they do not know, and any
//! layout change bumps the byte.

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::SolveProbe;
use crate::util::crc32::crc32;

/// First four bytes of every checkpoint file.
pub const CKPT_MAGIC: [u8; 4] = *b"PCKP";

/// Format version written by this build.
pub const CKPT_VERSION: u8 = 1;

/// Resumable state of an iterative solve at one residual check.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Client-supplied idempotency key (the coordinator's journal is
    /// keyed by it).
    pub job_id: String,
    /// Solver kind string ([`crate::api::SolverKind`]`::as_str`), so a
    /// resume can refuse to splice state into a different algorithm.
    pub solver: String,
    /// Sweeps completed when the snapshot was taken.
    pub sweeps: u64,
    /// The solve seed (resume must not reshuffle randomized orders).
    pub seed: u64,
    /// The iterate.
    pub a: Vec<f32>,
    /// The maintained residual `e = y - Xa` as the solver tracked it.
    pub e: Vec<f32>,
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Bounds-checked forward reader over the checkpoint body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| bad("length overflow"))?;
        let s = self.buf.get(self.pos..end).ok_or_else(|| bad("checkpoint truncated"))?;
        self.pos = end;
        Ok(s)
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32s(&mut self, n: usize) -> io::Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| bad("length overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }
}

impl Checkpoint {
    /// Serialise to the on-disk layout (format docs above).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            4 + 1 + 2 + self.job_id.len() + 1 + self.solver.len() + 32
                + 4 * (self.a.len() + self.e.len())
                + 4,
        );
        out.extend_from_slice(&CKPT_MAGIC);
        out.push(CKPT_VERSION);
        let jid = self.job_id.as_bytes();
        out.extend_from_slice(&(jid.len().min(u16::MAX as usize) as u16).to_le_bytes());
        out.extend_from_slice(&jid[..jid.len().min(u16::MAX as usize)]);
        let kind = self.solver.as_bytes();
        out.push(kind.len().min(u8::MAX as usize) as u8);
        out.extend_from_slice(&kind[..kind.len().min(u8::MAX as usize)]);
        out.extend_from_slice(&self.sweeps.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.a.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.e.len() as u64).to_le_bytes());
        for v in &self.a {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.e {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and verify a serialised checkpoint. Rejects a bad magic, an
    /// unknown version, a short buffer, and any CRC mismatch.
    pub fn from_bytes(bytes: &[u8]) -> io::Result<Self> {
        if bytes.len() < 4 + 1 + 2 + 1 + 32 + 4 {
            return Err(bad("checkpoint too short"));
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().expect("4 bytes"));
        let actual = crc32(body);
        if stored != actual {
            return Err(bad(format!(
                "checkpoint crc mismatch: stored {stored:#010x}, computed {actual:#010x}"
            )));
        }
        if body[0..4] != CKPT_MAGIC {
            return Err(bad("not a checkpoint file (bad magic)"));
        }
        if body[4] != CKPT_VERSION {
            return Err(bad(format!("unknown checkpoint version {}", body[4])));
        }
        let mut cur = Cursor { buf: body, pos: 5 };
        let jlen = u16::from_le_bytes(cur.take(2)?.try_into().expect("2 bytes")) as usize;
        let job_id = String::from_utf8(cur.take(jlen)?.to_vec())
            .map_err(|_| bad("job id is not UTF-8"))?;
        let klen = cur.take(1)?[0] as usize;
        let solver = String::from_utf8(cur.take(klen)?.to_vec())
            .map_err(|_| bad("solver kind is not UTF-8"))?;
        let sweeps = cur.u64()?;
        let seed = cur.u64()?;
        let vars = cur.u64()? as usize;
        let obs = cur.u64()? as usize;
        let a = cur.f32s(vars)?;
        let e = cur.f32s(obs)?;
        if cur.pos != body.len() {
            return Err(bad("checkpoint has trailing bytes"));
        }
        Ok(Checkpoint { job_id, solver, sweeps, seed, a, e })
    }

    /// Write atomically: serialise to `<path>.tmp`, then rename over
    /// `path`. A crash at any point leaves either the old checkpoint or
    /// none — never a torn file.
    pub fn save_atomic(&self, path: &Path) -> io::Result<()> {
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)
    }

    /// Read and verify a checkpoint file.
    pub fn load(path: &Path) -> io::Result<Self> {
        Self::from_bytes(&fs::read(path)?)
    }
}

/// A [`SolveProbe`] that persists a [`Checkpoint`] every `every` sweeps.
///
/// Attach it (alone or inside a [`crate::obs::MultiProbe`]) to
/// [`crate::solver::SolveOptions::probe`]; it opts into the state hook
/// via `wants_state`, so solves without a checkpoint probe pay nothing.
/// Write failures are swallowed into [`CheckpointProbe::last_error`] —
/// durability is best-effort and must never abort a healthy solve.
pub struct CheckpointProbe {
    path: PathBuf,
    job_id: String,
    solver: String,
    seed: u64,
    every: usize,
    written: AtomicU64,
    last_error: Mutex<Option<String>>,
}

impl CheckpointProbe {
    /// Checkpoint to `path` every `every` sweeps (`every` is clamped to
    /// at least 1).
    pub fn new(
        path: impl Into<PathBuf>,
        job_id: impl Into<String>,
        solver: impl Into<String>,
        seed: u64,
        every: usize,
    ) -> Arc<Self> {
        Arc::new(CheckpointProbe {
            path: path.into(),
            job_id: job_id.into(),
            solver: solver.into(),
            seed,
            every: every.max(1),
            written: AtomicU64::new(0),
            last_error: Mutex::new(None),
        })
    }

    /// Checkpoints successfully written so far.
    pub fn written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    /// The most recent write failure, if any.
    pub fn last_error(&self) -> Option<String> {
        self.last_error.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
    }

    /// The checkpoint file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl SolveProbe for CheckpointProbe {
    fn on_sweep(&self, _sweep: usize, _residual_norm: f64, _elapsed_ns: u64) {}

    fn wants_state(&self) -> bool {
        true
    }

    fn on_state(&self, sweep: usize, a: &[f32], e: &[f32], r2: f64) {
        // Solvers only forward finite states, but a checkpoint of garbage
        // would poison every future resume — re-check here.
        if !r2.is_finite() || sweep % self.every != 0 {
            return;
        }
        let ck = Checkpoint {
            job_id: self.job_id.clone(),
            solver: self.solver.clone(),
            sweeps: sweep as u64,
            seed: self.seed,
            a: a.to_vec(),
            e: e.to_vec(),
        };
        match ck.save_atomic(&self.path) {
            Ok(()) => {
                self.written.fetch_add(1, Ordering::Relaxed);
            }
            Err(err) => {
                *self
                    .last_error
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner) =
                    Some(err.to_string());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            job_id: "job-abc".into(),
            solver: "bak".into(),
            sweeps: 42,
            seed: 0x5eed,
            a: vec![1.0, -2.5, 0.0, 3.25],
            e: vec![0.5, -0.125, 7.0],
        }
    }

    fn temp_ckpt(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "pallas_ckpt_{tag}_{}.ckpt",
            std::process::id()
        ));
        p
    }

    #[test]
    fn roundtrips_through_bytes_and_disk() {
        let ck = sample();
        assert_eq!(Checkpoint::from_bytes(&ck.to_bytes()).unwrap(), ck);
        let path = temp_ckpt("roundtrip");
        ck.save_atomic(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        let _ = fs::remove_file(path);
    }

    #[test]
    fn any_flipped_byte_is_rejected() {
        let bytes = sample().to_bytes();
        // Flip one byte in the payload region and one in the header: both
        // must fail the CRC before any field is trusted.
        for idx in [6usize, bytes.len() / 2] {
            let mut bad = bytes.clone();
            bad[idx] ^= 0x01;
            assert!(
                Checkpoint::from_bytes(&bad).is_err(),
                "flip at {idx} accepted"
            );
        }
    }

    #[test]
    fn truncated_and_junk_rejected() {
        let bytes = sample().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 5]).is_err());
        assert!(Checkpoint::from_bytes(&[]).is_err());
        assert!(Checkpoint::from_bytes(&[0u8; 64]).is_err());
        // Wrong version, CRC re-sealed so only the version check can fire.
        let mut wrong = bytes[..bytes.len() - 4].to_vec();
        wrong[4] = CKPT_VERSION + 1;
        let crc = crc32(&wrong);
        wrong.extend_from_slice(&crc.to_le_bytes());
        let err = Checkpoint::from_bytes(&wrong).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn save_atomic_replaces_and_leaves_no_temp() {
        let path = temp_ckpt("atomic");
        let mut ck = sample();
        ck.save_atomic(&path).unwrap();
        ck.sweeps = 100;
        ck.save_atomic(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().sweeps, 100);
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "temp file left behind");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn probe_writes_every_n_and_skips_non_finite() {
        let path = temp_ckpt("probe");
        let probe = CheckpointProbe::new(&path, "j1", "bak", 7, 2);
        assert!(probe.wants_state());
        probe.on_state(1, &[1.0], &[0.0], 1.0); // 1 % 2 != 0
        assert_eq!(probe.written(), 0);
        probe.on_state(2, &[1.0], &[0.0], f64::NAN); // never persist NaN
        assert_eq!(probe.written(), 0);
        probe.on_state(2, &[1.5], &[0.25], 1.0);
        assert_eq!(probe.written(), 1);
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.sweeps, 2);
        assert_eq!(ck.a, vec![1.5]);
        assert_eq!(ck.e, vec![0.25]);
        assert_eq!(ck.seed, 7);
        assert!(probe.last_error().is_none());
        let _ = fs::remove_file(path);
    }
}
