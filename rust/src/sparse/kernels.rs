//! Sparse BLAS-1/2 kernels: the O(nnz) coordinate-descent hot path.
//!
//! These mirror the dense kernels in [`crate::linalg::blas1`] — same
//! f32 `mul_add` accumulation so a sparse solve and a densified solve
//! agree to rounding — but touch only stored entries. The Algorithm-1
//! inner step on a sparse column is [`sp_dot_dense`] + [`sp_axpy_into_dense`]
//! over nnz(col) entries instead of obs.
//!
//! Matrix-level kernels (spmv/spmv_t, column norms) live as methods on
//! [`super::CscMat`]/[`super::CsrMat`] and delegate to these.

/// Gather dot product `<x_sparse, dense>`: `sum(vals[k] * dense[idx[k]])`.
///
/// Four independent accumulator lanes (the sparse analogue of
/// `blas1::dot`'s 8-lane unroll — gathers dominate here, so fewer lanes
/// suffice to break the FP dependency chain).
#[inline]
pub fn sp_dot_dense(idx: &[usize], vals: &[f32], dense: &[f32]) -> f32 {
    debug_assert_eq!(idx.len(), vals.len());
    let chunks = idx.len() / 4;
    let (ih, it) = idx.split_at(chunks * 4);
    let (vh, vt) = vals.split_at(chunks * 4);
    let mut acc = [0.0f32; 4];
    for (ic, vc) in ih.chunks_exact(4).zip(vh.chunks_exact(4)) {
        for k in 0..4 {
            acc[k] = vc[k].mul_add(dense[ic[k]], acc[k]);
        }
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for (&i, &v) in it.iter().zip(vt) {
        s = v.mul_add(dense[i], s);
    }
    s
}

/// Scatter axpy `dense[idx[k]] += alpha * vals[k]`.
#[inline]
pub fn sp_axpy_into_dense(alpha: f32, idx: &[usize], vals: &[f32], dense: &mut [f32]) {
    debug_assert_eq!(idx.len(), vals.len());
    for (&i, &v) in idx.iter().zip(vals) {
        dense[i] = v.mul_add(alpha, dense[i]);
    }
}

/// Fused sparse CD step: `da = <x_j, e> * cninv`, then `e -= da * x_j`,
/// touching only the column's stored entries — the sparse analogue of
/// `blas1::cd_step`, O(nnz(col)) instead of O(obs).
#[inline]
pub fn sp_cd_step(idx: &[usize], vals: &[f32], e: &mut [f32], cninv: f32) -> f32 {
    let da = sp_dot_dense(idx, vals, e) * cninv;
    if da != 0.0 {
        sp_axpy_into_dense(-da, idx, vals, e);
    }
    da
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas1;
    use crate::util::rng::Rng;

    /// A sparse vector (idx sorted, distinct) plus its dense expansion.
    fn sparse_and_dense(seed: u64, n: usize, k: usize) -> (Vec<usize>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let idx = rng.sample_indices(n, k.min(n));
        let vals: Vec<f32> = idx.iter().map(|_| rng.normal_f32()).collect();
        let mut dense = vec![0.0f32; n];
        for (&i, &v) in idx.iter().zip(&vals) {
            dense[i] = v;
        }
        (idx, vals, dense)
    }

    #[test]
    fn sp_dot_matches_dense_dot() {
        for (seed, n, k) in [(1, 50, 7), (2, 100, 0), (3, 64, 64), (4, 9, 5), (5, 200, 33)] {
            let (idx, vals, xd) = sparse_and_dense(seed, n, k);
            let mut rng = Rng::seed(seed + 100);
            let e: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let got = sp_dot_dense(&idx, &vals, &e);
            let want = blas1::dot(&xd, &e);
            assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()), "n={n} k={k}: {got} vs {want}");
        }
    }

    #[test]
    fn sp_axpy_matches_dense_axpy() {
        for (seed, n, k) in [(10, 40, 6), (11, 8, 8), (12, 100, 1)] {
            let (idx, vals, xd) = sparse_and_dense(seed, n, k);
            let mut rng = Rng::seed(seed + 100);
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut ys = base.clone();
            let mut yd = base.clone();
            sp_axpy_into_dense(-0.75, &idx, &vals, &mut ys);
            blas1::axpy(-0.75, &xd, &mut yd);
            for (s, d) in ys.iter().zip(&yd) {
                assert!((s - d).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sp_cd_step_matches_dense_cd_step() {
        let (idx, vals, xd) = sparse_and_dense(20, 80, 12);
        let cninv = 1.0 / blas1::nrm2_sq(&vals);
        let mut rng = Rng::seed(21);
        let base: Vec<f32> = (0..80).map(|_| rng.normal_f32()).collect();
        let mut es = base.clone();
        let mut ed = base.clone();
        let das = sp_cd_step(&idx, &vals, &mut es, cninv);
        let dad = blas1::cd_step(&xd, &mut ed, cninv);
        assert!((das - dad).abs() < 1e-4, "{das} vs {dad}");
        for (s, d) in es.iter().zip(&ed) {
            assert!((s - d).abs() < 1e-4);
        }
        // Residual component along the column is eliminated, as in dense CD.
        assert!(sp_dot_dense(&idx, &vals, &es).abs() < 1e-3);
    }

    #[test]
    fn sp_cd_step_reduces_residual() {
        let (idx, vals, _) = sparse_and_dense(30, 120, 20);
        let mut rng = Rng::seed(31);
        let mut e: Vec<f32> = (0..120).map(|_| rng.normal_f32()).collect();
        let before = blas1::sum_sq_f64(&e);
        sp_cd_step(&idx, &vals, &mut e, 1.0 / blas1::nrm2_sq(&vals));
        assert!(blas1::sum_sq_f64(&e) <= before + 1e-9);
    }

    #[test]
    fn empty_sparse_vector_is_noop() {
        let mut e = vec![1.0f32, 2.0, 3.0];
        assert_eq!(sp_dot_dense(&[], &[], &e), 0.0);
        sp_axpy_into_dense(5.0, &[], &[], &mut e);
        assert_eq!(e, vec![1.0, 2.0, 3.0]);
        assert_eq!(sp_cd_step(&[], &[], &mut e, 1.0), 0.0);
    }
}
