//! Native sparse solvers: the paper's column-action family plus its
//! iterative comparators running directly on compressed storage.
//!
//! Each function mirrors its dense counterpart's control flow exactly —
//! same options, same residual-check cadence, same tolerance/stall exits,
//! same [`SolveReport`] invariants (`e == y - X a` at exit, non-increasing
//! per-sweep history for the monotone methods) — but the per-step cost is
//! O(nnz(col)) / O(nnz(row)) instead of O(obs) / O(vars):
//!
//! * [`solve_bak_csc`] — Algorithm 1 on CSC (one gather-dot + scatter-axpy
//!   per column; a full sweep is O(nnz)).
//! * [`solve_bakp_csc`] — Algorithm 2's stale-block update on CSC.
//! * [`solve_kaczmarz_csr`] — randomized Kaczmarz on CSR rows.
//! * [`cgls_csc`] — CGLS via sparse matvec/matvec_t.

use crate::baselines::cgls::CglsReport;
use crate::linalg::blas1;
use crate::solver::{ColumnOrder, SolveOptions, SolveReport, StopReason};
use crate::util::rng::Rng;

use super::kernels::{sp_axpy_into_dense, sp_cd_step, sp_dot_dense};
use super::{CscMat, CsrMat};

/// Precompute 1/<x_j,x_j> over CSC columns; structurally empty or
/// numerically zero columns map to 0 (skipped, as in the dense solver).
pub fn colnorms_inv_csc(x: &CscMat) -> Vec<f32> {
    x.colnorms_sq()
        .iter()
        .map(|&v| if v > 0.0 { 1.0 / v } else { 0.0 })
        .collect()
}

/// Solve x a ≈ y with Algorithm 1 on sparse columns — O(nnz) per sweep.
pub fn solve_bak_csc(x: &CscMat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs, "y length must equal obs");
    let cninv = colnorms_inv_csc(x);
    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    solve_bak_csc_warm(x, &cninv, &mut a, &mut e, y, opts)
}

/// Warm-start variant of [`solve_bak_csc`]: continues from caller-provided
/// (a, e). The caller must guarantee `e == y - X a` on entry (checked in
/// debug builds).
pub fn solve_bak_csc_warm(
    x: &CscMat,
    cninv: &[f32],
    a: &mut Vec<f32>,
    e: &mut Vec<f32>,
    y: &[f32],
    opts: &SolveOptions,
) -> SolveReport {
    let vars = x.cols();
    debug_assert_eq!(a.len(), vars);
    debug_assert_eq!(e.len(), x.rows());
    #[cfg(debug_assertions)]
    {
        let xa = x.matvec(a);
        for ((&yi, &xi), &ei) in y.iter().zip(&xa).zip(e.iter()) {
            debug_assert!((yi - xi - ei).abs() < 1e-3, "warm start invariant e == y - Xa");
        }
    }

    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut rng = Rng::seed(opts.seed);
    let mut order: Vec<usize> = (0..vars).collect();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        if opts.order == ColumnOrder::Shuffled {
            rng.shuffle(&mut order);
        }
        for &j in &order {
            let cn = cninv[j];
            if cn == 0.0 {
                continue; // empty / zero column
            }
            let (idx, vals) = x.col(j);
            let da = sp_cd_step(idx, vals, e, cn);
            a[j] += da;
        }
        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, a, e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    SolveReport {
        a: std::mem::take(a),
        e: std::mem::take(e),
        history,
        y_norm_sq,
        sweeps,
        stop,
    }
}

/// Solve x a ≈ y with Algorithm 2 (stale in-block errors) on sparse
/// columns. The in-block phases run serially — per-column nnz is uneven,
/// so the dense path's fixed-chunk threading does not map over; the win
/// here is O(nnz) arithmetic, and `opts.threads` is ignored.
pub fn solve_bakp_csc(x: &CscMat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs, "y length must equal obs");
    assert!(opts.thr > 0, "thr must be positive");
    let cninv = colnorms_inv_csc(x);
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;

    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    let mut da = vec![0.0f32; opts.thr];
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        let mut j0 = 0;
        while j0 < vars {
            let width = opts.thr.min(vars - j0);
            // Phase 1: stale-error dots against the block's shared e.
            for (k, d) in da[..width].iter_mut().enumerate() {
                let (idx, vals) = x.col(j0 + k);
                *d = sp_dot_dense(idx, vals, &e) * cninv[j0 + k];
            }
            // Phase 2: e -= X_blk da, a += da.
            for (k, &d) in da[..width].iter().enumerate() {
                if d != 0.0 {
                    let (idx, vals) = x.col(j0 + k);
                    sp_axpy_into_dense(-d, idx, vals, &mut e);
                }
                a[j0 + k] += d;
            }
            j0 += width;
        }
        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(&e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, &a, &e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// Randomized Kaczmarz on CSR rows: Strohmer-Vershynin norm-weighted row
/// sampling, each projection O(nnz(row)). Mirrors the dense
/// `solver::solve_kaczmarz` (same sampling sequence per seed).
pub fn solve_kaczmarz_csr(x: &CsrMat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs);
    let mut rng = Rng::seed(opts.seed);
    let row_norms_sq = x.row_norms_sq();
    let total: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
    let y_norm_sq = blas1::sum_sq_f64(y);
    if total == 0.0 {
        // Structurally/numerically all-zero matrix (perfectly legal over
        // the x_coo wire): the sampling CDF below would be 0/0 NaNs and
        // panic inside a coordinator worker. Report the trivial iterate.
        let stop = if y_norm_sq == 0.0 { StopReason::Converged } else { StopReason::Stalled };
        return SolveReport {
            a: vec![0.0f32; vars],
            e: y.to_vec(),
            history: vec![y_norm_sq],
            y_norm_sq,
            sweeps: 0,
            stop,
        };
    }
    let mut cdf = Vec::with_capacity(obs);
    let mut acc = 0.0f64;
    for &v in &row_norms_sq {
        acc += v as f64 / total;
        cdf.push(acc);
    }

    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        for _ in 0..obs {
            let u = rng.uniform();
            let i = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(k) => k,
                Err(k) => k.min(obs - 1),
            };
            let nrm = row_norms_sq[i];
            if nrm == 0.0 {
                continue;
            }
            let (idx, vals) = x.row(i);
            let ri = y[i] - sp_dot_dense(idx, vals, &a);
            sp_axpy_into_dense(ri / nrm, idx, vals, &mut a);
        }
        sweeps = sweep + 1;
        let e = residual_csr(x, y, &a);
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    let e = residual_csr(x, y, &a);
    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

fn residual_csr(x: &CsrMat, y: &[f32], a: &[f32]) -> Vec<f32> {
    let xa = x.spmv(a);
    y.iter().zip(&xa).map(|(&yi, &xi)| yi - xi).collect()
}

/// CGLS on CSC storage: conjugate gradient on the normal equations with
/// O(nnz) matvec/matvec_t per iteration. Mirrors
/// [`crate::baselines::cgls::cgls_solve`].
pub fn cgls_csc(x: &CscMat, y: &[f32], max_iter: usize, tol: f64) -> CglsReport {
    cgls_csc_probed(x, y, max_iter, tol, &crate::obs::ProbeHandle::none())
}

/// [`cgls_csc`] with a per-iteration convergence probe (one CGLS
/// iteration counts as one "sweep").
pub fn cgls_csc_probed(
    x: &CscMat,
    y: &[f32],
    max_iter: usize,
    tol: f64,
    probe: &crate::obs::ProbeHandle,
) -> CglsReport {
    let (m, n) = x.shape();
    assert_eq!(y.len(), m);
    let mut a = vec![0.0f32; n];
    let mut r = y.to_vec();
    let mut s = x.matvec_t(&r);
    let mut p = s.clone();
    let mut gamma = blas1::sum_sq_f64(&s);
    let gamma0 = gamma;
    let mut history = Vec::with_capacity(max_iter);
    let mut converged = false;
    let mut iterations = 0;
    let t0 = std::time::Instant::now();

    for _ in 0..max_iter {
        iterations += 1;
        let q = x.matvec(&p);
        let qq = blas1::sum_sq_f64(&q);
        if qq == 0.0 {
            converged = true;
            break;
        }
        let alpha = (gamma / qq) as f32;
        blas1::axpy(alpha, &p, &mut a);
        blas1::axpy(-alpha, &q, &mut r);
        let r2 = blas1::sum_sq_f64(&r);
        history.push(r2);
        probe.observe(iterations, r2, t0);
        s = x.matvec_t(&r);
        let gamma_new = blas1::sum_sq_f64(&s);
        if gamma_new <= tol * tol * gamma0 {
            converged = true;
            break;
        }
        let beta = (gamma_new / gamma) as f32;
        for (pi, &si) in p.iter_mut().zip(&s) {
            *pi = si + beta * *pi;
        }
        gamma = gamma_new;
    }
    CglsReport { a, history, iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{solve_bak, solve_bakp, solve_kaczmarz};
    use crate::sparse::CooBuilder;
    use crate::util::prop::{forall, DimCase};
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    /// Planted consistent sparse system: (csc, y, a_true). One shared
    /// generator — [`crate::bench::workload::SparseWorkload`] — so the
    /// tested distribution is exactly the benched one.
    fn planted_sparse(
        seed: u64,
        obs: usize,
        vars: usize,
        density: f64,
    ) -> (CscMat, Vec<f32>, Vec<f32>) {
        let w = crate::bench::workload::SparseWorkload::uniform(
            crate::bench::workload::WorkloadSpec::new(obs, vars, seed),
            density,
        );
        (w.x, w.y, w.a_true)
    }

    #[test]
    fn bak_csc_recovers_planted_solution() {
        let (x, y, a_true) = planted_sparse(800, 400, 40, 0.1);
        let rep = solve_bak_csc(&x, &y, &SolveOptions::accurate());
        assert!(rep.converged(), "stop={:?} rel={}", rep.stop, rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3, "err={}", rel_l2(&rep.a, &a_true));
    }

    #[test]
    fn bak_csc_matches_dense_bak_exactly_per_sweep() {
        // Same arithmetic order (columns ascending-row sorted == dense
        // order) -> per-sweep agreement to f32 rounding.
        let (x, y, _) = planted_sparse(801, 120, 16, 0.2);
        let dense = x.to_dense();
        let mut o = SolveOptions::default();
        o.max_sweeps = 4;
        o.tol = 0.0;
        let rs = solve_bak_csc(&x, &y, &o);
        let rd = solve_bak(&dense, &y, &o);
        assert_eq!(rs.sweeps, rd.sweeps);
        for (s, d) in rs.a.iter().zip(&rd.a) {
            assert!((s - d).abs() < 1e-4, "{s} vs {d}");
        }
    }

    #[test]
    fn bak_csc_history_monotone() {
        let (x, y, _) = planted_sparse(802, 150, 30, 0.15);
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 30;
        let rep = solve_bak_csc(&x, &y, &o);
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "Theorem 1 violated: {w:?}");
        }
    }

    #[test]
    fn bak_csc_exit_invariant() {
        let (x, y, _) = planted_sparse(803, 100, 20, 0.2);
        let rep = solve_bak_csc(&x, &y, &SolveOptions::default());
        let xa = x.matvec(&rep.a);
        for ((yi, xi), ei) in y.iter().zip(&xa).zip(&rep.e) {
            assert!((yi - xi - ei).abs() < 1e-3);
        }
    }

    #[test]
    fn bak_csc_warm_start_continues() {
        let (x, y, a_true) = planted_sparse(804, 200, 15, 0.15);
        let cninv = colnorms_inv_csc(&x);
        let mut a = a_true.clone();
        let xa = x.matvec(&a);
        let mut e: Vec<f32> = y.iter().zip(&xa).map(|(&yi, &xi)| yi - xi).collect();
        let mut o = SolveOptions::default();
        o.max_sweeps = 1;
        o.tol = 0.0;
        let rep = solve_bak_csc_warm(&x, &cninv, &mut a, &mut e, &y, &o);
        assert!(rep.rel_residual() < 1e-4, "warm from truth stays at truth");
    }

    #[test]
    fn bakp_csc_matches_dense_bakp() {
        let (x, y, _) = planted_sparse(805, 90, 18, 0.25);
        let dense = x.to_dense();
        let mut o = SolveOptions::default();
        o.thr = 6;
        o.max_sweeps = 3;
        o.tol = 0.0;
        let rs = solve_bakp_csc(&x, &y, &o);
        let rd = solve_bakp(&dense, &y, &o);
        for (s, d) in rs.a.iter().zip(&rd.a) {
            assert!((s - d).abs() < 1e-4, "{s} vs {d}");
        }
    }

    #[test]
    fn bakp_csc_converges() {
        let (x, y, a_true) = planted_sparse(806, 500, 64, 0.08);
        let mut o = SolveOptions::accurate();
        o.thr = 8;
        let rep = solve_bakp_csc(&x, &y, &o);
        assert!(rep.converged(), "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn kaczmarz_csr_matches_dense_kaczmarz() {
        // Same seed -> same row-sampling sequence -> same iterates.
        let (x, y, _) = planted_sparse(807, 60, 20, 0.3);
        let csr = x.to_csr();
        let dense = x.to_dense();
        let mut o = SolveOptions::default();
        o.max_sweeps = 3;
        o.tol = 0.0;
        let rs = solve_kaczmarz_csr(&csr, &y, &o);
        let rd = solve_kaczmarz(&dense, &y, &o);
        assert_eq!(rs.sweeps, rd.sweeps);
        for (s, d) in rs.a.iter().zip(&rd.a) {
            assert!((s - d).abs() < 1e-3, "{s} vs {d}");
        }
    }

    #[test]
    fn kaczmarz_csr_converges_square() {
        let (x, y, a_true) = planted_sparse(808, 80, 40, 0.2);
        let csr = x.to_csr();
        let mut o = SolveOptions::default();
        o.max_sweeps = 400;
        o.tol = 1e-5;
        let rep = solve_kaczmarz_csr(&csr, &y, &o);
        assert!(rep.rel_residual() < 1e-3, "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 0.05);
    }

    #[test]
    fn cgls_csc_matches_dense_cgls() {
        let (x, y, a_true) = planted_sparse(809, 200, 20, 0.15);
        let dense = x.to_dense();
        let rs = cgls_csc(&x, &y, 100, 1e-8);
        let rd = crate::baselines::cgls::cgls_solve(&dense, &y, 100, 1e-8);
        assert!(rs.converged && rd.converged);
        assert!(rel_l2(&rs.a, &a_true) < 1e-3);
        assert!(rel_l2(&rs.a, &rd.a) < 1e-3);
    }

    #[test]
    fn empty_column_skipped() {
        let mut b = CooBuilder::new(30, 3);
        let mut rng = Rng::seed(810);
        for i in 0..30 {
            b.push(i, 0, rng.normal_f32());
            b.push(i, 2, rng.normal_f32());
        }
        let x = b.to_csc(); // column 1 structurally empty
        let y: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
        let rep = solve_bak_csc(&x, &y, &SolveOptions::default());
        assert_eq!(rep.a[1], 0.0);
        assert!(rep.a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kaczmarz_csr_empty_matrix_does_not_panic() {
        // No stored entries at all — legal over the x_coo wire path.
        let csr = CooBuilder::new(4, 3).to_csr();
        let rep = solve_kaczmarz_csr(&csr, &[1.0, 2.0, 3.0, 4.0], &SolveOptions::default());
        assert_eq!(rep.a, vec![0.0; 3]);
        assert_eq!(rep.stop, crate::solver::StopReason::Stalled);
        let rep = solve_kaczmarz_csr(&csr, &[0.0; 4], &SolveOptions::default());
        assert_eq!(rep.stop, crate::solver::StopReason::Converged);
    }

    #[test]
    fn prop_sparse_dense_bak_agree() {
        forall(
            811,
            15,
            |rng| DimCase::draw(rng, 60, 12),
            |case| {
                let (x, y, _) = planted_sparse(case.seed, case.obs.max(4), case.vars, 0.3);
                let dense = x.to_dense();
                let mut o = SolveOptions::default();
                o.max_sweeps = 3;
                o.tol = 0.0;
                let rs = solve_bak_csc(&x, &y, &o);
                let rd = solve_bak(&dense, &y, &o);
                for (s, d) in rs.a.iter().zip(&rd.a) {
                    if !(s - d).abs().is_finite() || (s - d).abs() > 2e-3 {
                        return Err(format!("sparse {s} vs dense {d}"));
                    }
                }
                Ok(())
            },
        );
    }
}
