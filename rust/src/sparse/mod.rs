//! Sparse matrix storage + kernels — the O(nnz) regime of the paper's
//! column-action method.
//!
//! Algorithm 1's inner step is one dot + one axpy over a *single column*;
//! on a sparse column that is O(nnz(col)), so a whole sweep drops from
//! O(obs*vars) to O(nnz). This module provides the storage to exploit
//! that:
//!
//! * [`CooBuilder`] — validated triplet accumulation (the construction and
//!   wire format), lowering to the compressed forms with duplicate
//!   coordinates summed and indices sorted.
//! * [`CscMat`] — compressed sparse column: contiguous `(row_idx, val)`
//!   per column, the natural layout for SolveBak's column actions (the
//!   sparse analogue of the col-major [`Mat`]).
//! * [`CsrMat`] — compressed sparse row: the layout for Kaczmarz row
//!   actions and row-wise spmv.
//! * [`kernels`] — sparse BLAS-1/2 (`sp_dot_dense`, `sp_axpy_into_dense`,
//!   `sp_cd_step`, spmv/spmv_t) matching the dense kernels' accumulation
//!   semantics (f32 `mul_add` chains; residual tracking stays f64 via
//!   `blas1::sum_sq_f64`).
//! * [`solve`] — native sparse implementations of SolveBak, SolveBakP,
//!   randomized Kaczmarz, and CGLS sharing `SolveOptions`/`SolveReport`
//!   with the dense solver family.
//!
//! Dense interop: [`CscMat::to_dense`]/[`CscMat::from_dense`] bridge to
//! [`Mat`] for backends without a native sparse path (the api layer logs a
//! warning and the coordinator counts `densified_jobs` when that fallback
//! fires).

pub mod kernels;
pub mod solve;

pub use kernels::{sp_axpy_into_dense, sp_cd_step, sp_dot_dense};
pub use solve::{cgls_csc, solve_bak_csc, solve_bakp_csc, solve_kaczmarz_csr};

use crate::linalg::Mat;

/// Triplet (COO) accumulator: push `(row, col, val)` entries in any order,
/// then lower to [`CscMat`]/[`CsrMat`]. Duplicate coordinates are summed
/// during compression; indices are validated on entry.
#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    rows: usize,
    cols: usize,
    entries: Vec<(usize, usize, f32)>,
}

impl CooBuilder {
    /// Empty builder for a `rows x cols` matrix.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, entries: Vec::new() }
    }

    /// Add one entry. Panics on out-of-range indices (mirrors [`Mat`]'s
    /// assert-on-misuse contract); use [`CooBuilder::from_triplets`] for
    /// fallible wire-format construction.
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        assert!(row < self.rows, "row {row} out of range (rows={})", self.rows);
        assert!(col < self.cols, "col {col} out of range (cols={})", self.cols);
        self.entries.push((row, col, val));
    }

    /// Build from parallel triplet slices, validating lengths, index
    /// bounds, and value finiteness — the coordinator's `x_coo` wire path.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        row_idx: &[usize],
        col_idx: &[usize],
        vals: &[f32],
    ) -> Result<Self, String> {
        if row_idx.len() != vals.len() || col_idx.len() != vals.len() {
            return Err(format!(
                "triplet length mismatch: rows={} cols={} vals={}",
                row_idx.len(),
                col_idx.len(),
                vals.len()
            ));
        }
        let mut b = Self::new(rows, cols);
        for ((&i, &j), &v) in row_idx.iter().zip(col_idx).zip(vals) {
            if i >= rows {
                return Err(format!("row index {i} out of range (rows={rows})"));
            }
            if j >= cols {
                return Err(format!("col index {j} out of range (cols={cols})"));
            }
            if !v.is_finite() {
                return Err("triplet value is not finite".into());
            }
            b.entries.push((i, j, v));
        }
        Ok(b)
    }

    /// Number of accumulated triplets (before duplicate summing).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Sorted, duplicate-summed entries keyed by `key(i, j)`; shared by
    /// the two compressions.
    fn merged_by<K: Ord + Copy>(&self, key: impl Fn(usize, usize) -> K) -> Vec<(usize, usize, f32)> {
        let mut ent = self.entries.clone();
        ent.sort_unstable_by_key(|&(i, j, _)| key(i, j));
        let mut merged: Vec<(usize, usize, f32)> = Vec::with_capacity(ent.len());
        for (i, j, v) in ent {
            match merged.last_mut() {
                Some(last) if last.0 == i && last.1 == j => last.2 += v,
                _ => merged.push((i, j, v)),
            }
        }
        merged
    }

    /// Lower to compressed sparse column (rows sorted within each column,
    /// duplicates summed).
    pub fn to_csc(&self) -> CscMat {
        let merged = self.merged_by(|i, j| (j, i));
        let mut col_ptr = vec![0usize; self.cols + 1];
        for &(_, j, _) in &merged {
            col_ptr[j + 1] += 1;
        }
        for j in 0..self.cols {
            col_ptr[j + 1] += col_ptr[j];
        }
        let row_idx = merged.iter().map(|&(i, _, _)| i).collect();
        let vals = merged.iter().map(|&(_, _, v)| v).collect();
        CscMat { rows: self.rows, cols: self.cols, col_ptr, row_idx, vals }
    }

    /// Lower to compressed sparse row (cols sorted within each row,
    /// duplicates summed).
    pub fn to_csr(&self) -> CsrMat {
        let merged = self.merged_by(|i, j| (i, j));
        let mut row_ptr = vec![0usize; self.rows + 1];
        for &(i, _, _) in &merged {
            row_ptr[i + 1] += 1;
        }
        for i in 0..self.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let col_idx = merged.iter().map(|&(_, j, _)| j).collect();
        let vals = merged.iter().map(|&(_, _, v)| v).collect();
        CsrMat { rows: self.rows, cols: self.cols, row_ptr, col_idx, vals }
    }
}

/// Compressed sparse column f32 matrix: per column j, the nonzero rows
/// `row_idx[col_ptr[j]..col_ptr[j+1]]` (sorted ascending) and their values.
///
/// The column-action analogue of the col-major [`Mat`]: [`CscMat::col`]
/// is one contiguous `(indices, values)` pair, so the Algorithm-1 inner
/// step is O(nnz(col)).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMat {
    rows: usize,
    cols: usize,
    /// len == cols + 1; column j spans [col_ptr[j], col_ptr[j+1]).
    col_ptr: Vec<usize>,
    /// len == nnz; row index of each stored value.
    row_idx: Vec<usize>,
    /// len == nnz.
    vals: Vec<f32>,
}

impl CscMat {
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// nnz / (rows * cols); 0 for an empty shape.
    pub fn density(&self) -> f64 {
        let cells = self.rows * self.cols;
        if cells == 0 {
            0.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    /// Column j as `(row_indices, values)` — the sparse hot path.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f32]) {
        debug_assert!(j < self.cols);
        let (lo, hi) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[lo..hi], &self.vals[lo..hi])
    }

    /// All stored values (for finiteness scans).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.vals
    }

    /// <x_j, x_j> for every column (O(nnz)).
    pub fn colnorms_sq(&self) -> Vec<f32> {
        (0..self.cols)
            .map(|j| crate::linalg::blas1::nrm2_sq(self.col(j).1))
            .collect()
    }

    /// y = X a, accumulated column-by-column (scatter; O(nnz)).
    pub fn matvec(&self, a: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0f32; self.rows];
        for (j, &aj) in a.iter().enumerate() {
            if aj != 0.0 {
                let (idx, vals) = self.col(j);
                kernels::sp_axpy_into_dense(aj, idx, vals, &mut y);
            }
        }
        y
    }

    /// out = Xᵀ v, one gather-dot per column (O(nnz)).
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows, "matvec_t dim mismatch");
        (0..self.cols)
            .map(|j| {
                let (idx, vals) = self.col(j);
                kernels::sp_dot_dense(idx, vals, v)
            })
            .collect()
    }

    /// Materialise as a dense col-major [`Mat`] (O(rows*cols) memory —
    /// the densification fallback for backends without a sparse path).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (idx, vals) = self.col(j);
            let col = m.col_mut(j);
            for (&i, &v) in idx.iter().zip(vals) {
                col[i] = v;
            }
        }
        m
    }

    /// Compress a dense matrix, dropping exact zeros.
    pub fn from_dense(x: &Mat) -> CscMat {
        let (rows, cols) = x.shape();
        let mut col_ptr = Vec::with_capacity(cols + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::new();
        col_ptr.push(0);
        for j in 0..cols {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v != 0.0 {
                    row_idx.push(i);
                    vals.push(v);
                }
            }
            col_ptr.push(vals.len());
        }
        CscMat { rows, cols, col_ptr, row_idx, vals }
    }

    /// Convert to CSR by counting-sort transpose (O(nnz)); column indices
    /// come out sorted within each row.
    pub fn to_csr(&self) -> CsrMat {
        let mut row_ptr = vec![0usize; self.rows + 1];
        for &i in &self.row_idx {
            row_ptr[i + 1] += 1;
        }
        for i in 0..self.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut next = row_ptr.clone();
        let mut col_idx = vec![0usize; self.nnz()];
        let mut vals = vec![0.0f32; self.nnz()];
        for j in 0..self.cols {
            let (idx, vs) = self.col(j);
            for (&i, &v) in idx.iter().zip(vs) {
                let p = next[i];
                col_idx[p] = j;
                vals[p] = v;
                next[i] += 1;
            }
        }
        CsrMat { rows: self.rows, cols: self.cols, row_ptr, col_idx, vals }
    }

    /// Approximate memory footprint in bytes.
    pub fn nbytes(&self) -> usize {
        self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.row_idx.len() * std::mem::size_of::<usize>()
            + self.vals.len() * std::mem::size_of::<f32>()
    }
}

/// Compressed sparse row f32 matrix: per row i, the nonzero columns
/// `col_idx[row_ptr[i]..row_ptr[i+1]]` (sorted ascending) and values —
/// the layout for Kaczmarz row projections and row-wise spmv.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    /// len == rows + 1; row i spans [row_ptr[i], row_ptr[i+1]).
    row_ptr: Vec<usize>,
    /// len == nnz; column index of each stored value.
    col_idx: Vec<usize>,
    /// len == nnz.
    vals: Vec<f32>,
}

impl CsrMat {
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row i as `(col_indices, values)` — the row-action hot path.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f32]) {
        debug_assert!(i < self.rows);
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// All stored values (for finiteness scans).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.vals
    }

    /// <row_i, row_i> for every row (O(nnz)).
    pub fn row_norms_sq(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| crate::linalg::blas1::nrm2_sq(self.row(i).1))
            .collect()
    }

    /// y = X a, one gather-dot per row (O(nnz)).
    pub fn spmv(&self, a: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.cols, "spmv dim mismatch");
        (0..self.rows)
            .map(|i| {
                let (idx, vals) = self.row(i);
                kernels::sp_dot_dense(idx, vals, a)
            })
            .collect()
    }

    /// out = Xᵀ v, accumulated row-by-row (scatter; O(nnz)).
    pub fn spmv_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows, "spmv_t dim mismatch");
        let mut out = vec![0.0f32; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                let (idx, vals) = self.row(i);
                kernels::sp_axpy_into_dense(vi, idx, vals, &mut out);
            }
        }
        out
    }

    /// Materialise as a dense col-major [`Mat`].
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Approximate memory footprint in bytes.
    pub fn nbytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<usize>()
            + self.vals.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, DimCase};
    use crate::util::rng::Rng;

    /// Random COO over a DimCase: ~density fraction of cells, PLUS
    /// deliberate duplicate coordinates to exercise summing.
    fn random_coo(case: &DimCase, density: f64, dups: usize) -> CooBuilder {
        let mut rng = Rng::seed(case.seed);
        let mut b = CooBuilder::new(case.obs, case.vars);
        for i in 0..case.obs {
            for j in 0..case.vars {
                if rng.uniform() < density {
                    b.push(i, j, rng.normal_f32());
                }
            }
        }
        for _ in 0..dups {
            b.push(rng.below(case.obs), rng.below(case.vars), rng.normal_f32());
        }
        b
    }

    /// Dense reference accumulation of the builder's triplets.
    fn dense_of(b: &CooBuilder) -> Mat {
        let (rows, cols) = b.shape();
        let mut m = Mat::zeros(rows, cols);
        for &(i, j, v) in &b.entries {
            *m.get_mut(i, j) += v;
        }
        m
    }

    #[test]
    fn small_csc_layout() {
        // [[1, 0], [0, 2], [3, 0]]
        let mut b = CooBuilder::new(3, 2);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 0, 3.0);
        let m = b.to_csc();
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.col(0), (&[0usize, 2][..], &[1.0f32, 3.0][..]));
        assert_eq!(m.col(1), (&[1usize][..], &[2.0f32][..]));
        assert_eq!(m.to_dense(), Mat::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 2.0],
            vec![3.0, 0.0],
        ]));
    }

    #[test]
    fn duplicate_coordinates_sum() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 1, 1.5);
        b.push(0, 1, 2.5);
        b.push(1, 0, -1.0);
        let csc = b.to_csc();
        assert_eq!(csc.nnz(), 2);
        assert_eq!(csc.to_dense().get(0, 1), 4.0);
        let csr = b.to_csr();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.to_dense().get(0, 1), 4.0);
    }

    #[test]
    fn empty_rows_and_cols_roundtrip() {
        // Only the middle cell is set: row 0/2 and col 0/2 stay empty.
        let mut b = CooBuilder::new(3, 3);
        b.push(1, 1, 7.0);
        let csc = b.to_csc();
        assert_eq!(csc.col(0), (&[][..], &[][..]));
        assert_eq!(csc.col(2), (&[][..], &[][..]));
        let csr = csc.to_csr();
        assert_eq!(csr.row(0), (&[][..], &[][..]));
        assert_eq!(csr.row(1), (&[1usize][..], &[7.0f32][..]));
        assert_eq!(csr.to_dense(), csc.to_dense());
    }

    #[test]
    fn wholly_empty_matrix() {
        let b = CooBuilder::new(4, 3);
        let csc = b.to_csc();
        assert_eq!(csc.nnz(), 0);
        assert_eq!(csc.density(), 0.0);
        assert_eq!(csc.matvec(&[1.0, 2.0, 3.0]), vec![0.0; 4]);
        assert_eq!(csc.matvec_t(&[1.0; 4]), vec![0.0; 3]);
        let csr = b.to_csr();
        assert_eq!(csr.spmv(&[1.0, 2.0, 3.0]), vec![0.0; 4]);
    }

    #[test]
    fn from_triplets_validates() {
        assert!(CooBuilder::from_triplets(2, 2, &[0], &[0, 1], &[1.0]).is_err());
        assert!(CooBuilder::from_triplets(2, 2, &[2], &[0], &[1.0]).is_err());
        assert!(CooBuilder::from_triplets(2, 2, &[0], &[2], &[1.0]).is_err());
        assert!(CooBuilder::from_triplets(2, 2, &[0], &[0], &[f32::NAN]).is_err());
        let b = CooBuilder::from_triplets(2, 2, &[0, 1], &[1, 0], &[3.0, 4.0]).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b.to_csc().to_dense().get(0, 1), 3.0);
    }

    #[test]
    #[should_panic]
    fn push_out_of_range_panics() {
        CooBuilder::new(2, 2).push(2, 0, 1.0);
    }

    #[test]
    fn from_dense_roundtrip_drops_zeros() {
        let mut rng = Rng::seed(31);
        let mut x = Mat::randn(&mut rng, 10, 6);
        x.col_mut(2).fill(0.0);
        x.set(5, 4, 0.0);
        let s = CscMat::from_dense(&x);
        assert_eq!(s.to_dense(), x);
        assert_eq!(s.nnz(), 10 * 6 - 10 - 1);
        assert!(s.nbytes() > 0);
    }

    #[test]
    fn prop_coo_to_csc_csr_roundtrip_preserves_entries() {
        forall(
            101,
            40,
            |rng| DimCase::draw(rng, 30, 12),
            |case| {
                let b = random_coo(case, 0.25, 5);
                let want = dense_of(&b);
                let csc = b.to_csc();
                let csr = b.to_csr();
                if csc.to_dense() != want {
                    return Err("csc roundtrip mismatch".into());
                }
                if csr.to_dense() != want {
                    return Err("csr roundtrip mismatch".into());
                }
                if csc.to_csr().to_dense() != want {
                    return Err("csc->csr mismatch".into());
                }
                // Row indices sorted within each column.
                for j in 0..csc.cols() {
                    let (idx, _) = csc.col(j);
                    if !idx.windows(2).all(|w| w[0] < w[1]) {
                        return Err(format!("col {j} rows not strictly sorted"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_matvec_matches_dense() {
        forall(
            102,
            40,
            |rng| DimCase::draw(rng, 40, 16),
            |case| {
                let b = random_coo(case, 0.3, 3);
                let csc = b.to_csc();
                let csr = b.to_csr();
                let dense = csc.to_dense();
                let mut rng = Rng::seed(case.seed ^ 0xabc);
                let a: Vec<f32> = (0..case.vars).map(|_| rng.normal_f32()).collect();
                let v: Vec<f32> = (0..case.obs).map(|_| rng.normal_f32()).collect();
                let tol = 1e-4f32;
                for (got, want) in csc.matvec(&a).iter().zip(dense.matvec(&a)) {
                    if (got - want).abs() > tol * (1.0 + want.abs()) {
                        return Err(format!("csc matvec {got} vs {want}"));
                    }
                }
                for (got, want) in csr.spmv(&a).iter().zip(dense.matvec(&a)) {
                    if (got - want).abs() > tol * (1.0 + want.abs()) {
                        return Err(format!("csr spmv {got} vs {want}"));
                    }
                }
                for (got, want) in csc.matvec_t(&v).iter().zip(dense.matvec_t(&v)) {
                    if (got - want).abs() > tol * (1.0 + want.abs()) {
                        return Err(format!("csc matvec_t {got} vs {want}"));
                    }
                }
                for (got, want) in csr.spmv_t(&v).iter().zip(dense.matvec_t(&v)) {
                    if (got - want).abs() > tol * (1.0 + want.abs()) {
                        return Err(format!("csr spmv_t {got} vs {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_norms_match_dense() {
        forall(
            103,
            30,
            |rng| DimCase::draw(rng, 30, 10),
            |case| {
                let b = random_coo(case, 0.4, 2);
                let csc = b.to_csc();
                let dense = csc.to_dense();
                for (got, want) in csc.colnorms_sq().iter().zip(dense.colnorms_sq()) {
                    if (got - want).abs() > 1e-4 * (1.0 + want) {
                        return Err(format!("colnorm {got} vs {want}"));
                    }
                }
                let csr = csc.to_csr();
                for (i, &got) in csr.row_norms_sq().iter().enumerate() {
                    let want: f32 = dense.row(i).iter().map(|&v| v * v).sum();
                    if (got - want).abs() > 1e-4 * (1.0 + want) {
                        return Err(format!("rownorm {got} vs {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn density_and_shape_accessors() {
        let mut b = CooBuilder::new(4, 5);
        b.push(0, 0, 1.0);
        b.push(3, 4, 2.0);
        let m = b.to_csc();
        assert_eq!(m.rows(), 4);
        assert_eq!(m.cols(), 5);
        assert_eq!(m.shape(), (4, 5));
        assert!((m.density() - 2.0 / 20.0).abs() < 1e-12);
        let r = m.to_csr();
        assert_eq!(r.shape(), (4, 5));
        assert_eq!(r.nnz(), 2);
        assert!(r.nbytes() > 0);
    }
}
