//! Benchmark timing: warmup + multi-sample measurement loops in the style
//! of Julia BenchmarkTools (`@btime`), which the paper uses.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Configuration for a measurement loop.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Warmup runs (not recorded).
    pub warmup: usize,
    /// Recorded samples.
    pub samples: usize,
    /// Stop early once this much total time has been spent measuring.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: 1,
            samples: 10, // the paper runs each method ten times
            max_total: Duration::from_secs(60),
        }
    }
}

impl BenchConfig {
    /// Quick configuration for CI / smoke runs.
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 3, max_total: Duration::from_secs(10) }
    }
}

/// Time a closure once, returning seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run the warmup + sampling loop; returns per-sample seconds.
pub fn sample(cfg: &BenchConfig, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut out = Vec::with_capacity(cfg.samples);
    let start = Instant::now();
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
        if start.elapsed() > cfg.max_total && !out.is_empty() {
            break;
        }
    }
    out
}

/// Sample and summarize in one call.
pub fn bench(cfg: &BenchConfig, f: impl FnMut()) -> Summary {
    Summary::of(&sample(cfg, f))
}

/// Pretty seconds: 1.23 s / 45.6 ms / 789 us.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_output_and_positive_time() {
        let (v, t) = time_once(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(t >= 0.0);
    }

    #[test]
    fn sample_count_respected() {
        let cfg = BenchConfig { warmup: 0, samples: 5, max_total: Duration::from_secs(10) };
        let s = sample(&cfg, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn warmup_runs_happen() {
        let mut calls = 0;
        let cfg = BenchConfig { warmup: 2, samples: 3, max_total: Duration::from_secs(10) };
        let _ = sample(&cfg, || calls += 1);
        assert_eq!(calls, 5);
    }

    #[test]
    fn max_total_stops_early() {
        let cfg = BenchConfig {
            warmup: 0,
            samples: 1000,
            max_total: Duration::from_millis(50),
        };
        let s = sample(&cfg, || std::thread::sleep(Duration::from_millis(20)));
        assert!(s.len() < 1000, "stopped after {} samples", s.len());
        assert!(!s.is_empty());
    }

    #[test]
    fn fmt_seconds_units() {
        assert!(fmt_seconds(2.5).ends_with(" s"));
        assert!(fmt_seconds(0.0025).ends_with(" ms"));
        assert!(fmt_seconds(2.5e-6).ends_with(" us"));
        assert!(fmt_seconds(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_summary_sane() {
        let s = bench(&BenchConfig::quick(), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.max);
    }
}
