//! Allocation-counting global allocator.
//!
//! Table 1 of the paper reports "Memory Allocations (MiB)" per solve (the
//! Julia `@btime` allocation counter). This module reproduces that metric:
//! a global allocator wrapper that counts bytes and call counts, plus a
//! scope guard for measuring a closure.
//!
//! The counter is enabled by the bench binaries via
//! `#[global_allocator] static A: CountingAlloc = CountingAlloc;`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static BYTES_ALLOCATED: AtomicU64 = AtomicU64::new(0);
static NUM_ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Global allocator wrapper that tallies every allocation.
pub struct CountingAlloc;

// SAFETY: defers entirely to the System allocator; only adds atomic counters.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES_ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        NUM_ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // Count only growth, matching Julia's "bytes allocated" semantics.
        if new_size > layout.size() {
            BYTES_ALLOCATED.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
        }
        NUM_ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Snapshot of the allocation counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub bytes: u64,
    pub count: u64,
}

/// Read the current counters.
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        bytes: BYTES_ALLOCATED.load(Ordering::Relaxed),
        count: NUM_ALLOCATIONS.load(Ordering::Relaxed),
    }
}

/// Allocation delta produced by running `f`.
///
/// Only meaningful when the binary installs [`CountingAlloc`] as the global
/// allocator; otherwise both fields are zero.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, AllocSnapshot) {
    let before = snapshot();
    let out = f();
    let after = snapshot();
    (
        out,
        AllocSnapshot {
            bytes: after.bytes - before.bytes,
            count: after.count - before.count,
        },
    )
}

/// Bytes -> MiB, as reported in Table 1.
pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Peak resident set size of this process in bytes: the `VmHWM` high-water
/// mark from `/proc/self/status` on Linux. Returns `None` when the metric
/// is unavailable — non-Linux platforms, an unreadable `/proc/self/status`,
/// or a missing/malformed `VmHWM` line — so callers omit the field instead
/// of recording a bogus zero. This is the number the out-of-core benches
/// and the CI `stream-smoke` budget check record — unlike the allocation
/// counters above it captures what the OS actually had resident, including
/// the streaming chunk buffers.
pub fn peak_rss_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        let rest = status.lines().find_map(|l| l.strip_prefix("VmHWM:"))?;
        let kb = rest.trim().trim_end_matches("kB").trim();
        kb.parse::<u64>().ok().map(|kb| kb * 1024)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the unit-test binary does not install CountingAlloc, so the
    // counters stay zero here; the arithmetic and monotonicity of the API
    // are still testable, and the end-to-end behaviour is covered by the
    // bench binaries (which do install it).

    #[test]
    fn snapshot_monotone() {
        let a = snapshot();
        let _v: Vec<u8> = Vec::with_capacity(1024);
        let b = snapshot();
        assert!(b.bytes >= a.bytes);
        assert!(b.count >= a.count);
    }

    #[test]
    fn measure_returns_value() {
        let (v, d) = measure(|| vec![0u8; 4096].len());
        assert_eq!(v, 4096);
        // Without the global allocator installed the delta is 0; with it,
        // at least 4096. Both are valid here.
        assert!(d.bytes == 0 || d.bytes >= 4096);
    }

    #[test]
    fn peak_rss_some_on_linux_none_elsewhere() {
        let v = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // A running test process has megabytes resident.
            let v = v.expect("VmHWM available on Linux");
            assert!(v > 1024 * 1024, "VmHWM = {v}");
        } else {
            assert_eq!(v, None);
        }
    }

    #[test]
    fn mib_conversion() {
        assert!((mib(1024 * 1024) - 1.0).abs() < 1e-12);
        assert!((mib(0)).abs() < 1e-12);
        assert!((mib(512 * 1024) - 0.5).abs() < 1e-12);
    }
}
