//! CRC32 (IEEE 802.3, polynomial 0xEDB88320) implemented in-tree — the
//! offline registry carries no checksum crates. Used by the `.sbck` chunk
//! store (per-chunk integrity words) and the `.ckpt` checkpoint format
//! (whole-file trailer).
//!
//! The table is built at first use behind a `OnceLock`; hashing is the
//! classic byte-at-a-time table walk, which is plenty for the chunk sizes
//! involved (a few MiB per checksum at most).

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// Streaming CRC32 state. `Hasher::new()` → repeated [`Hasher::update`] →
/// [`Hasher::finalize`]; equivalent to [`crc32`] over the concatenation.
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in bytes {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Check values from the IEEE CRC32 reference ("check" = 0xCBF43926).
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0u32..4096).map(|i| (i % 251) as u8).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(97) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![7u8; 1024];
        let before = crc32(&data);
        data[512] ^= 0x10;
        assert_ne!(crc32(&data), before);
    }
}
