//! Robust summary statistics for benchmark samples.
//!
//! Mirrors what Julia's BenchmarkTools (`@btime`) reports — the paper's
//! timings are minimum-over-samples — plus median/MAD/mean/stddev and the
//! MAPE accuracy metric of Table 1.

/// Summary of a set of samples (times in seconds, or any positive metric).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0);
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary { n, min, max, mean, median, mad, stddev: var.sqrt() }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice. p in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean Absolute Percentage Error between a solution and the truth —
/// the "Accuracy (MAPE)" column of Table 1. Entries where |truth| < eps
/// are skipped (percentage error undefined at 0).
pub fn mape(estimate: &[f32], truth: &[f32]) -> f64 {
    assert_eq!(estimate.len(), truth.len());
    let eps = 1e-12f32;
    let mut sum = 0.0f64;
    let mut cnt = 0usize;
    for (&a, &t) in estimate.iter().zip(truth) {
        if t.abs() > eps {
            sum += ((a - t) / t).abs() as f64;
            cnt += 1;
        }
    }
    if cnt == 0 { 0.0 } else { sum / cnt as f64 }
}

/// Relative L2 error ||a - t|| / ||t||.
pub fn rel_l2(estimate: &[f32], truth: &[f32]) -> f64 {
    assert_eq!(estimate.len(), truth.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&a, &t) in estimate.iter().zip(truth) {
        num += ((a - t) as f64).powi(2);
        den += (t as f64).powi(2);
    }
    if den == 0.0 { num.sqrt() } else { (num / den).sqrt() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }

    #[test]
    fn median_even_count_interpolates() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.1, 0.9, 1.0, 100.0]);
        assert!(s.mad < 0.2, "mad={}", s.mad);
        assert!(s.stddev > 10.0);
    }

    #[test]
    fn mape_exact_is_zero() {
        let v = [1.0f32, -2.0, 3.0];
        assert_eq!(mape(&v, &v), 0.0);
    }

    #[test]
    fn mape_known_value() {
        // estimate 1.1 vs truth 1.0 -> 10% each.
        let e = [1.1f32, 2.2];
        let t = [1.0f32, 2.0];
        assert!((mape(&e, &t) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn mape_skips_zero_truth() {
        let e = [5.0f32, 1.1];
        let t = [0.0f32, 1.0];
        assert!((mape(&e, &t) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn rel_l2_basics() {
        let t = [3.0f32, 4.0];
        assert_eq!(rel_l2(&t, &t), 0.0);
        let e = [3.0f32, 4.0 + 5.0];
        assert!((rel_l2(&e, &t) - 1.0).abs() < 1e-6); // ||(0,5)||/||(3,4)|| = 1
    }
}
