//! Tiny leveled logger (the offline registry has `log` but no emitter;
//! this is self-contained and used by the coordinator + benches).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for relative timestamps.
fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global minimum level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Read the global minimum level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// Emit a record (used by the macros).
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if level < self::level() {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag} {target}] {msg}");
}

/// Log at INFO.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $target,
                                format_args!($($arg)*))
    };
}

/// Log at DEBUG.
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $target,
                                format_args!($($arg)*))
    };
}

/// Log at WARN.
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $target,
                                format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let orig = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(orig);
    }

    #[test]
    fn ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn emit_does_not_panic() {
        emit(Level::Error, "test", format_args!("hello {}", 1));
    }
}
