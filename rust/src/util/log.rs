//! Tiny leveled logger (the offline registry has `log` but no emitter;
//! this is self-contained and used by the coordinator + benches).
//!
//! Setting `PALLAS_LOG_FORMAT=json` switches every record to one JSON
//! object per line (`{"ts": ..., "level": ..., "target": ..., "msg": ...}`)
//! so log shippers can ingest them without a parser; records emitted via
//! [`emit_traced`] additionally carry the solve's `trace_id`, joining log
//! lines to the span timelines returned by the coordinator's `traces`
//! command.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for relative timestamps.
fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global minimum level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Read the global minimum level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// True when `PALLAS_LOG_FORMAT=json` was set at first emit (cached —
/// the format cannot flip mid-process).
fn json_format() -> bool {
    static JSON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *JSON.get_or_init(|| {
        std::env::var("PALLAS_LOG_FORMAT").map(|v| v == "json").unwrap_or(false)
    })
}

/// Render one record. Pure (no clock, no env, no IO) so both formats are
/// unit-testable; `emit_traced` supplies the elapsed time and format flag.
fn format_record(
    json: bool,
    t: f64,
    level: Level,
    target: &str,
    trace_id: Option<u64>,
    msg: &str,
) -> String {
    if json {
        let mut b = crate::util::json::ObjBuilder::new()
            .num("ts", t)
            .str(
                "level",
                match level {
                    Level::Debug => "debug",
                    Level::Info => "info",
                    Level::Warn => "warn",
                    Level::Error => "error",
                },
            )
            .str("target", target)
            .str("msg", msg);
        if let Some(id) = trace_id {
            b = b.num("trace_id", id as f64);
        }
        return b.build().to_string();
    }
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    match trace_id {
        Some(id) => format!("[{t:9.3}s {tag} {target}] (trace {id}) {msg}"),
        None => format!("[{t:9.3}s {tag} {target}] {msg}"),
    }
}

/// Emit a record (used by the macros).
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    emit_traced(level, target, None, msg);
}

/// Emit a record tied to a traced solve: in JSON mode the line carries a
/// `trace_id` field, in text mode a `(trace N)` prefix, so operators can
/// grep a request's logs from its trace id (and vice versa).
pub fn emit_traced(
    level: Level,
    target: &str,
    trace_id: Option<u64>,
    msg: std::fmt::Arguments<'_>,
) {
    if level < self::level() {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let line = format_record(json_format(), t, level, target, trace_id, &msg.to_string());
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

/// Log at INFO.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $target,
                                format_args!($($arg)*))
    };
}

/// Log at DEBUG.
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $target,
                                format_args!($($arg)*))
    };
}

/// Log at WARN.
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $target,
                                format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let orig = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(orig);
    }

    #[test]
    fn ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn emit_does_not_panic() {
        emit(Level::Error, "test", format_args!("hello {}", 1));
        emit_traced(Level::Error, "test", Some(42), format_args!("traced"));
    }

    #[test]
    fn text_format_with_and_without_trace() {
        let plain = format_record(false, 1.5, Level::Info, "server", None, "started");
        assert!(plain.contains("INFO"));
        assert!(plain.contains("server"));
        assert!(plain.contains("started"));
        assert!(!plain.contains("trace"));
        let traced = format_record(false, 1.5, Level::Warn, "service", Some(7), "slow");
        assert!(traced.contains("(trace 7)"));
        assert!(traced.contains("WARN"));
    }

    #[test]
    fn json_format_is_parseable_and_escapes() {
        let line = format_record(
            true,
            0.25,
            Level::Error,
            "server",
            Some(99),
            "bad \"quoted\" input",
        );
        let j = crate::util::json::Json::parse(&line).expect("valid json log line");
        assert_eq!(j.get("level").unwrap().as_str(), Some("error"));
        assert_eq!(j.get("target").unwrap().as_str(), Some("server"));
        assert_eq!(j.get("ts").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("trace_id").unwrap().as_f64(), Some(99.0));
        assert_eq!(j.get("msg").unwrap().as_str(), Some("bad \"quoted\" input"));
    }

    #[test]
    fn json_format_omits_trace_id_when_absent() {
        let line = format_record(true, 0.0, Level::Debug, "t", None, "m");
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert!(j.get("trace_id").is_none());
    }
}
