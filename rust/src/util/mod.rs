//! Infrastructure substrates built in-repo (the offline registry carries no
//! rand/serde/criterion/clap): PRNG, robust timing statistics, an
//! allocation-counting global allocator, a minimal JSON reader/writer, and
//! a tiny logging facility.

pub mod crc32;
pub mod rng;
pub mod stats;
pub mod alloc;
pub mod json;
pub mod log;
pub mod timer;
pub mod prop;
