//! Minimal JSON reader/writer (no serde offline).
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and serializes bench/metric results. Supports the full JSON grammar
//! except for `\u` surrogate pairs beyond the BMP (not needed here, but
//! handled gracefully by substitution).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for JSON objects.
#[derive(Default)]
pub struct ObjBuilder(BTreeMap<String, Json>);

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.0.insert(k.into(), Json::Str(v.into()));
        self
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.0.insert(k.into(), Json::Num(v));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.0.insert(k.into(), Json::Bool(v));
        self
    }
    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.0.insert(k.into(), v);
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(self.err(&format!("bad escape '\\{}'", c as char))),
                    }
                    self.i += 1;
                }
                _ => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().items().len(), 3);
        assert_eq!(
            j.get("a").unwrap().items()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap().items().len(), 0);
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"s":"he\"llo"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(-5.0).as_usize(), None);
        assert_eq!(Json::Num(5.5).as_usize(), None);
    }

    #[test]
    fn builder() {
        let j = ObjBuilder::new()
            .str("name", "t1")
            .num("time", 1.5)
            .bool("ok", true)
            .build();
        assert_eq!(j.get("name").unwrap().as_str(), Some("t1"));
        assert_eq!(j.get("time").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 1,
          "artifacts": [
            {"name": "bakp_sweep_256x64", "kind": "bakp_sweep",
             "obs": 256, "vars": 64, "width": 32, "dtype": "f32",
             "file": "bakp_sweep_256x64.hlo.txt",
             "inputs": ["x", "cninv", "a", "e"],
             "outputs": ["a", "e", "r2"]}
          ]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let a = &j.get("artifacts").unwrap().items()[0];
        assert_eq!(a.get("obs").unwrap().as_usize(), Some(256));
        assert_eq!(a.get("inputs").unwrap().items().len(), 4);
    }
}
