//! Minimal property-based testing support (no proptest offline).
//!
//! [`forall`] runs a check over many seeded random cases; on failure it
//! greedily *shrinks* the failing case (halving each numeric field) and
//! reports the smallest still-failing case, proptest-style.

use super::rng::Rng;

/// A test case that can present itself and shrink.
pub trait Case: Clone + std::fmt::Debug {
    /// Candidate smaller versions of this case (tried in order).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Run `check` on `n` random cases drawn by `gen`. Panics with the
/// smallest failing case found.
pub fn forall<C: Case>(
    seed: u64,
    n: usize,
    mut gen: impl FnMut(&mut Rng) -> C,
    mut check: impl FnMut(&C) -> Result<(), String>,
) {
    let mut rng = Rng::seed(seed);
    for i in 0..n {
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            // Shrink loop: first failing shrink candidate, repeat.
            let mut smallest = case.clone();
            let mut err = msg;
            'outer: loop {
                for cand in smallest.shrink() {
                    if let Err(m) = check(&cand) {
                        smallest = cand;
                        err = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed on case {i}/{n}\n  original: {case:?}\n  shrunk:   {smallest:?}\n  error:    {err}"
            );
        }
    }
}

/// A standard case shape for solver properties: random system dims + seed.
#[derive(Clone, Debug)]
pub struct DimCase {
    pub obs: usize,
    pub vars: usize,
    pub seed: u64,
}

impl Case for DimCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.obs > 2 {
            out.push(Self { obs: self.obs / 2, ..self.clone() });
        }
        if self.vars > 1 {
            out.push(Self { vars: self.vars / 2, ..self.clone() });
        }
        if self.obs > 2 && self.vars > 1 {
            out.push(Self { obs: self.obs / 2, vars: self.vars / 2, ..self.clone() });
        }
        out
    }
}

impl DimCase {
    /// Draw with obs in [2, max_obs], vars in [1, max_vars].
    pub fn draw(rng: &mut Rng, max_obs: usize, max_vars: usize) -> Self {
        Self {
            obs: 2 + rng.below(max_obs.saturating_sub(1).max(1)),
            vars: 1 + rng.below(max_vars.max(1)),
            seed: rng.next_u64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |rng| DimCase::draw(rng, 100, 20),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert!(count >= 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(
            2,
            10,
            |rng| DimCase::draw(rng, 100, 20),
            |c| if c.obs >= 2 { Err("always".into()) } else { Ok(()) },
        );
    }

    #[test]
    fn shrink_reduces_dims() {
        let c = DimCase { obs: 64, vars: 32, seed: 9 };
        let shrunk = c.shrink();
        assert!(shrunk.iter().any(|s| s.obs == 32));
        assert!(shrunk.iter().any(|s| s.vars == 16));
    }

    #[test]
    fn shrink_bottoms_out() {
        let c = DimCase { obs: 2, vars: 1, seed: 0 };
        assert!(c.shrink().is_empty());
    }

    #[test]
    #[should_panic]
    fn shrinking_finds_smaller_case() {
        // Fails whenever vars >= 4; shrinker should land near vars=4.
        forall(
            3,
            20,
            |rng| DimCase::draw(rng, 50, 64),
            |c| if c.vars >= 4 { Err(format!("vars={}", c.vars)) } else { Ok(()) },
        );
    }
}
