//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so this module implements the
//! generators the benchmarks and workload generators need:
//!
//! * [`SplitMix64`] — seed expander (Steele, Lea & Flood 2014).
//! * [`Rng`] — xoshiro256++ (Blackman & Vigna 2019): fast, 256-bit state,
//!   passes BigCrush; plus uniform/normal/permutation helpers.
//!
//! Everything is reproducible from a `u64` seed, which the bench harness
//! records in its output so any table row can be regenerated exactly.

/// SplitMix64: used to expand a 64-bit seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG with sampling helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a seed via SplitMix64 expansion (never all-zero state).
    pub fn seed(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Next 64 random bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bench workloads, not cryptography): map 64 bits into [0,n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p.sort_unstable();
        p
    }

    /// Split off an independent generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::seed(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public domain
        // splitmix64.c reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn deterministic_from_seed() {
        let mut r1 = Rng::seed(99);
        let mut r2 = Rng::seed(99);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut r1 = Rng::seed(1);
        let mut r2 = Rng::seed(2);
        let same = (0..64).filter(|_| r1.next_u64() == r2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed(10);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::seed(11);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::seed(12);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_are_independent() {
        let mut r = Rng::seed(13);
        let mut c1 = r.split();
        let mut c2 = r.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Rng::seed(14);
        let mut v: Vec<u32> = (0..50).map(|i| i % 7).collect();
        let mut orig = v.clone();
        r.shuffle(&mut v);
        orig.sort_unstable();
        let mut shuf = v.clone();
        shuf.sort_unstable();
        assert_eq!(orig, shuf);
    }
}
