//! `solvebak` binary: CLI front-end over the coordinator + solver library.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(solvebak::cli::run(argv));
}
