//! Algorithm 3 — SolveBakF: greedy feature selection.
//!
//! Each round scores EVERY feature with one fused pass (the score of
//! feature j is the regression sum of squares `<x_j,e>^2 / <x_j,x_j>`,
//! exactly the residual reduction of a single BAK step), picks the argmax,
//! refits the selected set by exact least squares (Cholesky on the small
//! Gram system, line 7), and refreshes the residual.
//!
//! Cost per round: O(obs*vars) for the scoring pass + O(k^2 obs) for the
//! refit — versus forward stepwise's O(vars * k^2 * obs). Figure 2's
//! speedup is this ratio.

use crate::baselines::cholesky::solve_normal_equations;
use crate::linalg::{blas1, residual, Mat};

use super::colnorms_inv;

/// Outcome of SolveBakF selection.
#[derive(Clone, Debug)]
pub struct BakfReport {
    /// Selected feature indices, in selection order.
    pub selected: Vec<usize>,
    /// Coefficients of the final least-squares refit (aligned with
    /// `selected`).
    pub coeffs: Vec<f32>,
    /// Squared residual after each round.
    pub history: Vec<f64>,
    /// Final residual vector.
    pub e: Vec<f32>,
}

/// Options for SolveBakF.
#[derive(Clone, Debug)]
pub struct BakfOptions {
    /// Number of features to select (the paper's `max_feat`).
    pub max_feat: usize,
    /// Stop early once the relative squared residual drops below this.
    pub tol: f64,
    /// Ridge added to the refit Gram system (numerical safety).
    pub ridge: f32,
}

impl Default for BakfOptions {
    fn default() -> Self {
        Self { max_feat: 10, tol: 0.0, ridge: 1e-6 }
    }
}

/// Run Algorithm 3. Scores with the fused pass, refits exactly.
pub fn select_features_bakf(x: &Mat, y: &[f32], opts: &BakfOptions) -> BakfReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs);
    let max_feat = opts.max_feat.min(vars);
    let cninv = colnorms_inv(x);
    let y2 = blas1::sum_sq_f64(y);

    let mut e = y.to_vec();
    let mut selected: Vec<usize> = Vec::with_capacity(max_feat);
    let mut taken = vec![false; vars];
    let mut coeffs: Vec<f32> = Vec::new();
    let mut history = Vec::with_capacity(max_feat);

    for _ in 0..max_feat {
        // Line 3-5: score every feature in one Xᵀe pass.
        let g = x.matvec_t(&e);
        let mut best_j = usize::MAX;
        let mut best_score = -1.0f32;
        for j in 0..vars {
            if taken[j] {
                continue;
            }
            let score = g[j] * g[j] * cninv[j];
            if score > best_score {
                best_score = score;
                best_j = j;
            }
        }
        if best_j == usize::MAX || best_score <= 0.0 {
            break; // nothing reduces the residual further
        }
        selected.push(best_j);
        taken[best_j] = true;

        // Line 7: exact LS refit on the selected columns.
        let xs = x.select_cols(&selected);
        match solve_normal_equations(&xs, y, opts.ridge) {
            Ok(a) => {
                e = residual(&xs, y, &a);
                coeffs = a;
            }
            Err(_) => {
                // Collinear pick (can happen with ridge=0): drop it and stop.
                selected.pop();
                taken[best_j] = false;
                break;
            }
        }
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        if opts.tol > 0.0 && r2 <= opts.tol * y2 {
            break;
        }
    }

    BakfReport { selected, coeffs, history, e }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn planted(seed: u64, obs: usize, vars: usize, support: &[(usize, f32)]) -> (Mat, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let mut y = vec![0.0f32; obs];
        for &(j, w) in support {
            blas1::axpy(w, x.col(j), &mut y);
        }
        (x, y)
    }

    #[test]
    fn recovers_planted_support() {
        let (x, y) = planted(300, 400, 32, &[(5, 2.0), (12, -1.0), (29, 0.5)]);
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 3, ..Default::default() });
        let mut s = rep.selected.clone();
        s.sort_unstable();
        assert_eq!(s, vec![5, 12, 29]);
        assert!(rep.history[2] < 1e-4 * blas1::sum_sq_f64(&y));
    }

    #[test]
    fn agrees_with_stepwise_on_clear_signal() {
        // With well-separated signal strengths both methods pick the same
        // set in the same order.
        let (x, y) = planted(301, 500, 24, &[(3, 4.0), (17, 2.0), (9, 1.0)]);
        let rep_f = select_features_bakf(&x, &y, &BakfOptions { max_feat: 3, ..Default::default() });
        let rep_s = crate::baselines::stepwise_select(&x, &y, 3);
        assert_eq!(rep_f.selected, rep_s.selected);
    }

    #[test]
    fn history_monotone() {
        let mut rng = Rng::seed(302);
        let x = Mat::randn(&mut rng, 200, 16);
        let y: Vec<f32> = (0..200).map(|_| rng.normal_f32()).collect();
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 8, ..Default::default() });
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6));
        }
    }

    #[test]
    fn tol_stops_early() {
        let (x, y) = planted(303, 300, 20, &[(2, 3.0)]);
        let rep = select_features_bakf(
            &x,
            &y,
            &BakfOptions { max_feat: 10, tol: 1e-6, ..Default::default() },
        );
        assert_eq!(rep.selected.len(), 1, "one feature explains everything");
    }

    #[test]
    fn max_feat_capped() {
        let mut rng = Rng::seed(304);
        let x = Mat::randn(&mut rng, 50, 5);
        let y: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 99, ..Default::default() });
        assert!(rep.selected.len() <= 5);
    }

    #[test]
    fn coeffs_close_to_planted_weights() {
        let (x, y) = planted(305, 600, 40, &[(7, 2.5), (31, -1.25)]);
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 2, ..Default::default() });
        for (idx, &j) in rep.selected.iter().enumerate() {
            let want = if j == 7 { 2.5 } else { -1.25 };
            assert!((rep.coeffs[idx] - want).abs() < 1e-2);
        }
    }

    #[test]
    fn duplicate_feature_never_selected() {
        let (x, y) = planted(306, 200, 10, &[(4, 1.0)]);
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 5, ..Default::default() });
        let mut s = rep.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), rep.selected.len());
    }

    #[test]
    fn final_e_consistent_with_refit() {
        let (x, y) = planted(307, 150, 12, &[(1, 1.0), (8, -2.0)]);
        let rep = select_features_bakf(&x, &y, &BakfOptions { max_feat: 4, ..Default::default() });
        let xs = x.select_cols(&rep.selected);
        let fresh = residual(&xs, &y, &rep.coeffs);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-4);
        }
    }
}
