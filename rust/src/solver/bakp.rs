//! Algorithm 2 — SolveBakP: the paper's parallel variant.
//!
//! Each sweep walks column blocks of width `thr`. Inside a block every
//! `da_k` is computed against the SAME (stale) error vector — those dots
//! are embarrassingly parallel — and the error is refreshed once per block
//! with `e -= X_blk da_blk` (line 9), parallelised over row chunks.
//!
//! The paper's convergence caveat is preserved and tested: the stale-error
//! update converges when `thr` is small relative to `vars` (for iid
//! Gaussian columns the in-block coupling is O(1/sqrt(obs)) so quite large
//! `thr` works; adversarially correlated columns can diverge — see
//! `tests/solver_properties.rs` and the thr-sweep ablation bench).

use crate::linalg::{blas1, blas2, Mat};

use super::{colnorms_inv, SolveOptions, SolveReport, StopReason};

/// Solve x a ≈ y with Algorithm 2 (SolveBakP).
///
/// `opts.thr` is the block width; `opts.threads > 1` runs the in-block dot
/// phase and the error refresh on scoped threads.
pub fn solve_bakp(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs, "y length must equal obs");
    assert!(opts.thr > 0, "thr must be positive");
    let cninv = colnorms_inv(x);
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;

    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    let mut da = vec![0.0f32; opts.thr];
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let threads = opts.threads.max(1);
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        let mut j0 = 0;
        while j0 < vars {
            let width = opts.thr.min(vars - j0);
            block_step(x, j0, width, &cninv, &mut a, &mut e, &mut da[..width], threads);
            j0 += width;
        }
        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(&e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, &a, &e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// One Algorithm-2 block update (lines 6-9), optionally threaded.
fn block_step(
    x: &Mat,
    j0: usize,
    width: usize,
    cninv: &[f32],
    a: &mut [f32],
    e: &mut [f32],
    da: &mut [f32],
    threads: usize,
) {
    // Phase 1: stale-error dots, "do in parallel" per the paper.
    // Threading pays only when the block is big enough to amortise spawn.
    let work = x.rows() * width;
    if threads > 1 && work >= 1 << 18 {
        let per = width.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in da.chunks_mut(per).enumerate() {
                let k0 = j0 + t * per;
                let e_ro: &[f32] = e;
                s.spawn(move || {
                    for (i, d) in chunk.iter_mut().enumerate() {
                        *d = blas1::dot(x.col(k0 + i), e_ro) * cninv[k0 + i];
                    }
                });
            }
        });
    } else {
        for (i, d) in da.iter_mut().enumerate() {
            *d = blas1::dot(x.col(j0 + i), e) * cninv[j0 + i];
        }
    }

    // Phase 2: line 9, e -= X_blk da (row-parallel), and a += da.
    if threads > 1 && work >= 1 << 18 {
        let rows = x.rows();
        let per = rows.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, ec) in e.chunks_mut(per).enumerate() {
                let r0 = t * per;
                let len = ec.len();
                let da_ro: &[f32] = da;
                s.spawn(move || {
                    for (i, &d) in da_ro.iter().enumerate() {
                        if d != 0.0 {
                            blas1::axpy(-d, &x.col(j0 + i)[r0..r0 + len], ec);
                        }
                    }
                });
            }
        });
    } else {
        for (i, &d) in da.iter().enumerate() {
            if d != 0.0 {
                blas1::axpy(-d, x.col(j0 + i), e);
            }
        }
    }
    for (i, &d) in da.iter().enumerate() {
        a[j0 + i] += d;
    }
    // Keep the shared helper in sync with this implementation.
    let _ = blas2::block_update; // (same semantics; used by the PJRT path tests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::solve_bak;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    #[test]
    fn converges_on_tall_system() {
        let (x, y, a_true) = planted(200, 500, 64);
        let mut o = SolveOptions::accurate();
        o.thr = 8;
        let rep = solve_bakp(&x, &y, &o);
        assert!(rep.converged(), "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn thr_one_matches_sequential_bak_exactly() {
        let (x, y, _) = planted(201, 80, 16);
        let mut o = SolveOptions::default();
        o.thr = 1;
        o.max_sweeps = 3;
        o.tol = 0.0;
        let rp = solve_bakp(&x, &y, &o);
        let rs = solve_bak(&x, &y, &o);
        for (p, s) in rp.a.iter().zip(&rs.a) {
            assert!((p - s).abs() < 1e-6, "thr=1 must equal Algorithm 1");
        }
    }

    #[test]
    fn thr_not_dividing_vars_handles_tail_block() {
        let (x, y, a_true) = planted(202, 300, 37); // 37 % 5 != 0
        let mut o = SolveOptions::accurate();
        o.thr = 5;
        let rep = solve_bakp(&x, &y, &o);
        assert!(rep.converged());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn thr_larger_than_vars_is_one_block() {
        let (x, y, a_true) = planted(203, 400, 16);
        let mut o = SolveOptions::accurate();
        o.thr = 64; // > vars
        o.max_sweeps = 2000;
        let rep = solve_bakp(&x, &y, &o);
        // Tall iid Gaussian: even full-width blocks converge (weak coupling).
        assert!(rep.rel_residual() < 1e-4);
        assert!(rel_l2(&rep.a, &a_true) < 1e-2);
    }

    #[test]
    fn threaded_matches_serial_numerically() {
        let (x, y, _) = planted(204, 3000, 128);
        let mut o = SolveOptions::default();
        o.thr = 64;
        o.max_sweeps = 3;
        o.tol = 0.0;
        o.threads = 1;
        let r1 = solve_bakp(&x, &y, &o);
        o.threads = 4;
        let r4 = solve_bakp(&x, &y, &o);
        // Same arithmetic, same order within each dot -> tight agreement.
        for (a, b) in r1.a.iter().zip(&r4.a) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn history_monotone_for_small_thr() {
        let (x, y, _) = planted(205, 200, 64);
        let mut o = SolveOptions::default();
        o.thr = 8;
        o.tol = 0.0;
        o.max_sweeps = 40;
        let rep = solve_bakp(&x, &y, &o);
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6));
        }
    }

    #[test]
    fn exit_invariant_e_equals_y_minus_xa() {
        let (x, y, _) = planted(206, 150, 40);
        let mut o = SolveOptions::default();
        o.thr = 10;
        let rep = solve_bakp(&x, &y, &o);
        let fresh = crate::linalg::residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-3);
        }
    }

    #[test]
    fn correlated_columns_with_large_thr_can_diverge_but_small_thr_saves_it() {
        // Build strongly correlated columns: x_j = base + small noise.
        let mut rng = Rng::seed(207);
        let obs = 100;
        let vars = 32;
        let base: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();
        let x = Mat::from_fn(obs, vars, |i, _| base[i] + 0.05 * rng.normal_f32());
        let y: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();

        // Large thr on near-identical columns: stale update massively
        // overshoots (every column "claims" the same correction).
        let mut big = SolveOptions::default();
        big.thr = 32;
        big.max_sweeps = 50;
        big.tol = 0.0;
        let rep_big = solve_bakp(&x, &y, &big);
        let r_big = rep_big.history.last().copied().unwrap_or(f64::INFINITY);

        // Small thr converges (the paper's §6 caveat).
        let mut small = big.clone();
        small.thr = 1;
        let rep_small = solve_bakp(&x, &y, &small);
        let r_small = rep_small.history.last().copied().unwrap();
        assert!(
            r_small.is_finite() && (r_big.is_nan() || r_small < r_big || r_big > 1e6),
            "small-thr should behave better: small={r_small} big={r_big}"
        );
    }

    #[test]
    fn wide_system_converges() {
        let (x, y, _) = planted(208, 64, 256);
        let mut o = SolveOptions::accurate();
        o.thr = 16;
        o.max_sweeps = 2000;
        let rep = solve_bakp(&x, &y, &o);
        assert!(rep.rel_residual() < 1e-4, "rel={}", rep.rel_residual());
    }
}
