//! Extensions of the basic algorithms that the paper sketches or implies
//! (§2 "other variations ... can also be implemented"), plus the natural
//! comparators from the same iterative-methods family:
//!
//! * [`solve_kaczmarz`] — randomized Kaczmarz, the ROW-action dual of
//!   SolveBak's column action (projects onto one equation per step).
//!   Ablation partner: which action wins depends on the aspect ratio.
//! * [`solve_gauss_southwell`] — greedy column choice: each step updates
//!   the column with the largest error reduction, computed with the same
//!   scoring pass as SolveBakF. Fewer sweeps, more work per sweep.
//! * [`solve_bakp_damped`] — SolveBakP with an under-relaxation factor
//!   that provably tames the stale-block overshoot the paper's §6 warns
//!   about (the thr-sweep ablation shows raw BAKP diverging on correlated
//!   columns; damping restores monotonicity).
//! * [`solve_bak_multi`] — multi-RHS SolveBak: shares the matrix walk
//!   across right-hand sides (one x_j load serves all systems), the
//!   solver-side analogue of the coordinator's same-matrix batching.

use crate::linalg::{blas1, Mat};
use crate::util::rng::Rng;

use super::{colnorms_inv, SolveOptions, SolveReport, StopReason};

/// Randomized Kaczmarz: at each step pick row i with probability
/// proportional to ||row_i||^2 (Strohmer-Vershynin) and project the
/// iterate onto its hyperplane.
///
/// Row-action on a column-major [`Mat`] strides, so this is also the
/// layout ablation: SolveBak's column action is contiguous, Kaczmarz is
/// not — part of why the paper's method benches so well in column-major
/// Julia. The strided access itself is unavoidable, but the hot loops go
/// through `blas1::{dot_strided, axpy_strided}` over the backing slice
/// (no per-element `get(i, j)` index arithmetic/bounds checks), and the
/// row-norm precompute runs column-major — one cache-friendly pass.
pub fn solve_kaczmarz(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs);
    let mut rng = Rng::seed(opts.seed);
    // ||row_i||^2 for all i in one column-major pass (sequential reads),
    // instead of obs strided row gathers.
    let mut row_norms_sq = vec![0.0f32; obs];
    for j in 0..vars {
        for (rn, &v) in row_norms_sq.iter_mut().zip(x.col(j)) {
            *rn = v.mul_add(v, *rn);
        }
    }
    let total: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
    let y_norm_sq = blas1::sum_sq_f64(y);
    if total == 0.0 {
        // All-zero matrix: no projection can move the iterate, and the
        // sampling distribution below would be 0/0 NaNs. Report the
        // trivial iterate instead of panicking mid-sample.
        let stop = if y_norm_sq == 0.0 { StopReason::Converged } else { StopReason::Stalled };
        return SolveReport {
            a: vec![0.0f32; vars],
            e: y.to_vec(),
            history: vec![y_norm_sq],
            y_norm_sq,
            sweeps: 0,
            stop,
        };
    }
    // Cumulative distribution for norm-weighted sampling.
    let mut cdf = Vec::with_capacity(obs);
    let mut acc = 0.0f64;
    for &v in &row_norms_sq {
        acc += v as f64 / total;
        cdf.push(acc);
    }

    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    // One "sweep" = obs row projections (comparable work to a BAK sweep
    // on square systems; obs/vars ratio otherwise).
    for sweep in 0..opts.max_sweeps {
        for _ in 0..obs {
            let u = rng.uniform();
            let i = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(k) => k,
                Err(k) => k.min(obs - 1),
            };
            let nrm = row_norms_sq[i];
            if nrm == 0.0 {
                continue;
            }
            // Row i of the col-major Mat: backing[i + j*obs] — one strided
            // view reused for both the residual and the update.
            let row = &x.as_slice()[i..];
            let ri = y[i] - blas1::dot_strided(row, obs, &a);
            blas1::axpy_strided(ri / nrm, row, obs, &mut a);
        }
        sweeps = sweep + 1;
        let e = crate::linalg::residual(x, y, &a);
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    let e = crate::linalg::residual(x, y, &a);
    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// Gauss-Southwell: each step updates the single column with the largest
/// score <x_j,e>^2/<x_j,x_j> (greedy instead of cyclic). One "sweep" =
/// vars greedy steps. The scoring pass costs a full Xᵀe per step, so this
/// is O(vars) times more expensive per update — included as the
/// convergence-per-update upper bound for column-action methods.
pub fn solve_gauss_southwell(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs);
    let cninv = colnorms_inv(x);
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        for _ in 0..vars {
            // Score all columns, pick the argmax.
            let g = x.matvec_t(&e);
            let mut best = 0usize;
            let mut best_score = -1.0f32;
            for j in 0..vars {
                let s = g[j] * g[j] * cninv[j];
                if s > best_score {
                    best_score = s;
                    best = j;
                }
            }
            if best_score <= 0.0 {
                break;
            }
            let da = g[best] * cninv[best];
            blas1::axpy(-da, x.col(best), &mut e);
            a[best] += da;
        }
        sweeps = sweep + 1;
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// SolveBakP with under-relaxation: the block update becomes
/// `a += damping * da_stale`. damping = 1 is the paper's Algorithm 2;
/// damping ~ 1/sqrt(in-block coupling) restores convergence for wide
/// blocks of correlated columns.
pub fn solve_bakp_damped(
    x: &Mat,
    y: &[f32],
    opts: &SolveOptions,
    damping: f32,
) -> SolveReport {
    assert!(damping > 0.0 && damping <= 1.0, "damping in (0,1]");
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs);
    let cninv = colnorms_inv(x);
    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    let mut da = vec![0.0f32; opts.thr];
    let mut history = Vec::new();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        let mut j0 = 0;
        while j0 < vars {
            let width = opts.thr.min(vars - j0);
            for (k, d) in da[..width].iter_mut().enumerate() {
                *d = blas1::dot(x.col(j0 + k), &e) * cninv[j0 + k] * damping;
            }
            for (k, &d) in da[..width].iter().enumerate() {
                if d != 0.0 {
                    blas1::axpy(-d, x.col(j0 + k), &mut e);
                }
                a[j0 + k] += d;
            }
            j0 += width;
        }
        sweeps = sweep + 1;
        let r2 = blas1::sum_sq_f64(&e);
        history.push(r2);
        opts.probe.observe(sweeps, r2, t0);
        if !r2.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        opts.probe.observe_state(sweeps, &a, &e, r2);
        if opts.cancel.is_cancelled() {
            stop = StopReason::Cancelled;
            break;
        }
        if opts.tol > 0.0 && r2 <= tol_sq {
            stop = StopReason::Converged;
            break;
        }
        if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
            stop = StopReason::Stalled;
            break;
        }
        prev_r2 = r2;
    }
    SolveReport { a, e, history, y_norm_sq, sweeps, stop }
}

/// Multi-RHS SolveBak: solves x A = Y for `nrhs` right-hand sides in one
/// matrix walk. Per column j, the single x_j load (one pass, cache-hot)
/// serves every RHS — the amortisation the coordinator's batcher exploits.
/// Returns one report per RHS.
pub fn solve_bak_multi(x: &Mat, ys: &[Vec<f32>], opts: &SolveOptions) -> Vec<SolveReport> {
    let (obs, vars) = x.shape();
    let nrhs = ys.len();
    for y in ys {
        assert_eq!(y.len(), obs, "every RHS must have obs rows");
    }
    let cninv = colnorms_inv(x);
    let mut a: Vec<Vec<f32>> = vec![vec![0.0f32; vars]; nrhs];
    let mut e: Vec<Vec<f32>> = ys.to_vec();
    let y_norm_sq: Vec<f64> = ys.iter().map(|y| blas1::sum_sq_f64(y)).collect();
    let mut history: Vec<Vec<f64>> = vec![Vec::new(); nrhs];
    let mut done: Vec<Option<StopReason>> = vec![None; nrhs];
    let mut prev_r2 = vec![f64::INFINITY; nrhs];
    let mut sweeps_done = vec![0usize; nrhs];
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        if done.iter().all(Option::is_some) {
            break;
        }
        for j in 0..vars {
            let cn = cninv[j];
            if cn == 0.0 {
                continue;
            }
            let xj = x.col(j);
            for r in 0..nrhs {
                if done[r].is_some() {
                    continue;
                }
                let da = blas1::dot(xj, &e[r]) * cn;
                blas1::axpy(-da, xj, &mut e[r]);
                a[r][j] += da;
            }
        }
        for r in 0..nrhs {
            if done[r].is_some() {
                continue;
            }
            sweeps_done[r] = sweep + 1;
            let r2 = blas1::sum_sq_f64(&e[r]);
            history[r].push(r2);
            if r == 0 {
                // Multi-RHS solves report the first system's trajectory
                // (members of a coalesced batch share the matrix walk).
                opts.probe.observe(sweeps_done[r], r2, t0);
                if r2.is_finite() {
                    opts.probe.observe_state(sweeps_done[r], &a[r], &e[r], r2);
                }
            }
            if !r2.is_finite() {
                done[r] = Some(StopReason::Breakdown);
            } else if opts.tol > 0.0 && r2 <= opts.tol * opts.tol * y_norm_sq[r] {
                done[r] = Some(StopReason::Converged);
            } else if r2 >= prev_r2[r] * (1.0 - 1e-9) && sweep > 0 {
                done[r] = Some(StopReason::Stalled);
            }
            prev_r2[r] = r2;
        }
        if opts.cancel.is_cancelled() {
            for d in done.iter_mut() {
                if d.is_none() {
                    *d = Some(StopReason::Cancelled);
                }
            }
            break;
        }
    }

    (0..nrhs)
        .map(|r| SolveReport {
            a: std::mem::take(&mut a[r]),
            e: std::mem::take(&mut e[r]),
            history: std::mem::take(&mut history[r]),
            y_norm_sq: y_norm_sq[r],
            sweeps: sweeps_done[r],
            stop: done[r].unwrap_or(StopReason::MaxSweeps),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::solve_bak;
    use crate::util::stats::rel_l2;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    #[test]
    fn kaczmarz_converges_square() {
        let (x, y, a_true) = planted(600, 80, 40);
        let mut o = SolveOptions::default();
        o.max_sweeps = 400;
        o.tol = 1e-5;
        let rep = solve_kaczmarz(&x, &y, &o);
        assert!(rep.rel_residual() < 1e-3, "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 0.05);
    }

    #[test]
    fn kaczmarz_history_monotone_ish() {
        // RK is monotone in expectation; per-sweep (obs projections) it is
        // strongly decreasing early on.
        let (x, y, _) = planted(601, 100, 20);
        let mut o = SolveOptions::default();
        o.max_sweeps = 5;
        o.tol = 0.0;
        let rep = solve_kaczmarz(&x, &y, &o);
        assert!(rep.history[rep.history.len() - 1] < rep.history[0]);
    }

    #[test]
    fn kaczmarz_all_zero_matrix_does_not_panic() {
        let x = Mat::zeros(5, 3);
        let y = vec![1.0f32; 5];
        let rep = solve_kaczmarz(&x, &y, &SolveOptions::default());
        assert_eq!(rep.a, vec![0.0; 3]);
        assert_eq!(rep.stop, StopReason::Stalled);
        assert!(rep.a.iter().all(|v| v.is_finite()));
        // Zero matrix + zero rhs counts as converged.
        let rep = solve_kaczmarz(&x, &[0.0; 5], &SolveOptions::default());
        assert_eq!(rep.stop, StopReason::Converged);
    }

    #[test]
    fn gauss_southwell_beats_cyclic_per_sweep() {
        // Greedy picks the best column each step -> at least as much
        // per-sweep residual reduction as cyclic on the first sweep.
        let (x, y, _) = planted(602, 120, 30);
        let mut o = SolveOptions::default();
        o.max_sweeps = 1;
        o.tol = 0.0;
        let gs = solve_gauss_southwell(&x, &y, &o);
        let cyc = solve_bak(&x, &y, &o);
        assert!(
            gs.history[0] <= cyc.history[0] * 1.05,
            "greedy {} vs cyclic {}",
            gs.history[0],
            cyc.history[0]
        );
    }

    #[test]
    fn gauss_southwell_converges() {
        let (x, y, a_true) = planted(603, 200, 20);
        let mut o = SolveOptions::accurate();
        o.max_sweeps = 200;
        let rep = solve_gauss_southwell(&x, &y, &o);
        assert!(rep.rel_residual() < 1e-4);
        assert!(rel_l2(&rep.a, &a_true) < 1e-2);
    }

    #[test]
    fn damped_bakp_fixes_correlated_wide_block() {
        // The §6 failure case: near-identical columns, full-width block.
        let mut rng = Rng::seed(604);
        let obs = 100;
        let vars = 32;
        let base: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();
        let x = Mat::from_fn(obs, vars, |i, _| base[i] + 0.05 * rng.normal_f32());
        let y: Vec<f32> = (0..obs).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.thr = vars; // one full-width stale block
        o.max_sweeps = 200;
        o.tol = 0.0;
        let raw = crate::solver::solve_bakp(&x, &y, &o);
        let damped = solve_bakp_damped(&x, &y, &o, 1.0 / vars as f32);
        let r_raw = raw.history.last().copied().unwrap_or(f64::INFINITY);
        let r_damped = damped.history.last().copied().unwrap();
        assert!(
            r_damped.is_finite() && (r_damped < r_raw || !r_raw.is_finite()),
            "damped {r_damped} vs raw {r_raw}"
        );
        // Damped history must be monotone.
        for w in damped.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6), "damped non-monotone {w:?}");
        }
    }

    #[test]
    fn damped_with_factor_one_equals_bakp() {
        let (x, y, _) = planted(605, 90, 18);
        let mut o = SolveOptions::default();
        o.thr = 6;
        o.max_sweeps = 3;
        o.tol = 0.0;
        let a1 = solve_bakp_damped(&x, &y, &o, 1.0);
        let a2 = crate::solver::solve_bakp(&x, &y, &o);
        for (p, q) in a1.a.iter().zip(&a2.a) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn multi_rhs_matches_individual_solves() {
        let (x, _, _) = planted(606, 150, 25);
        let mut rng = Rng::seed(607);
        let ys: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let a: Vec<f32> = (0..25).map(|_| rng.normal_f32()).collect();
                x.matvec(&a)
            })
            .collect();
        let mut o = SolveOptions::default();
        o.max_sweeps = 50;
        o.tol = 1e-6;
        let multi = solve_bak_multi(&x, &ys, &o);
        assert_eq!(multi.len(), 3);
        for (r, y) in ys.iter().enumerate() {
            let single = solve_bak(&x, y, &o);
            assert!(
                rel_l2(&multi[r].a, &single.a) < 1e-4,
                "rhs {r}: {}",
                rel_l2(&multi[r].a, &single.a)
            );
        }
    }

    #[test]
    fn multi_rhs_independent_convergence() {
        // An easy RHS (exact) and a hard one (noise): each stops on its
        // own criterion.
        let (x, y_easy, _) = planted(608, 200, 10);
        let mut rng = Rng::seed(609);
        let y_hard: Vec<f32> = (0..200).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.max_sweeps = 200;
        o.tol = 1e-6;
        let reps = solve_bak_multi(&x, &[y_easy, y_hard], &o);
        assert_eq!(reps[0].stop, StopReason::Converged);
        assert_eq!(reps[1].stop, StopReason::Stalled); // LS optimum, not 0
        assert!(reps[0].rel_residual() < 1e-4);
    }

    #[test]
    fn multi_rhs_empty_input() {
        let (x, _, _) = planted(610, 20, 4);
        let reps = solve_bak_multi(&x, &[], &SolveOptions::default());
        assert!(reps.is_empty());
    }
}
