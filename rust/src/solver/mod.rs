//! The paper's solver family (native L3 implementations):
//!
//! * [`solve_bak`] — Algorithm 1, sequential cyclic coordinate descent,
//!   with the paper's suggested variations (tolerance early-break,
//!   randomized column order).
//! * [`solve_bakp`] — Algorithm 2, the block-"parallel" variant with
//!   stale in-block errors, optionally multi-threaded.
//! * [`select_features_bakf`] — Algorithm 3, greedy feature selection.
//!
//! All solvers share [`SolveOptions`] / [`SolveReport`] and uphold the two
//! invariants the test-suite checks everywhere: the per-sweep squared
//! residual is non-increasing (Theorem 1), and `e == y - X a` at exit.
//!
//! These free functions are the stable primitive layer; the uniform
//! dispatch surface (trait objects, typed errors, and the per-kind
//! capability matrix — see the [`crate::api`] module docs) lives in
//! [`crate::api`], whose implementations delegate here. New call sites
//! should prefer `api::{Problem, Solver, SolverKind}`; the wrappers stay
//! so existing callers and the Python-side tests keep compiling.

pub mod bak;
pub mod bakp;
pub mod bakf;
pub mod variants;

pub use bak::solve_bak;
pub use bakf::{select_features_bakf, BakfOptions, BakfReport};
pub use bakp::solve_bakp;
pub use variants::{
    solve_bak_multi, solve_bakp_damped, solve_gauss_southwell, solve_kaczmarz,
};

use crate::linalg::blas1;

/// Column visit order for SolveBak.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ColumnOrder {
    /// The paper's serial order 1..vars.
    #[default]
    Cyclic,
    /// Fresh random permutation each sweep (§2's "randomly selected index"
    /// variation; helps on adversarial column orderings).
    Shuffled,
}

/// Options shared by the solver family.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Maximum number of full sweeps (the paper's `max_iter`).
    pub max_sweeps: usize,
    /// Early-break tolerance on the RELATIVE residual
    /// sqrt(sum e^2 / sum y^2); 0 disables the check.
    pub tol: f64,
    /// Column visit order (SolveBak only).
    pub order: ColumnOrder,
    /// Block width for SolveBakP (the paper's `thr`).
    pub thr: usize,
    /// Worker threads: SolveBakP's in-block loop, and the block count for
    /// the [`crate::parallel`] solvers (`bak_par` / `kaczmarz_par` /
    /// multi-RHS chunking). 1 = serial. The CLI/server default honours
    /// `PALLAS_THREADS`.
    pub threads: usize,
    /// Check the tolerance every this many sweeps (checking costs a pass
    /// over e; the paper's "control the accuracy and execution time").
    pub check_every: usize,
    /// Seed for the shuffled order.
    pub seed: u64,
    /// Optional per-sweep convergence observer
    /// ([`crate::obs::SolveProbe`]): iterative solvers report
    /// `(sweep, residual_norm, elapsed_ns)` at every residual check. The
    /// disabled default costs a single branch per sweep.
    pub probe: crate::obs::ProbeHandle,
    /// Cooperative cancellation ([`crate::robust::CancelToken`]): polled
    /// at the same residual-check points the probe observes, so an
    /// expired deadline stops the solve mid-run with
    /// [`StopReason::Cancelled`] and the best-so-far coefficients. The
    /// disabled default costs a single branch per check.
    pub cancel: crate::robust::CancelToken,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            max_sweeps: 100,
            tol: 1e-6,
            order: ColumnOrder::Cyclic,
            thr: 50, // the paper's value for experiments 1-10
            threads: 1,
            check_every: 1,
            seed: 0x5eed,
            probe: crate::obs::ProbeHandle::none(),
            cancel: crate::robust::CancelToken::none(),
        }
    }
}

impl SolveOptions {
    /// Options matching the paper's accuracy regime (MAPE ~1e-7 on
    /// consistent systems). tol 1e-6 is the practical f32 floor for the
    /// relative residual; tighter values just stall.
    pub fn accurate() -> Self {
        Self { max_sweeps: 1000, tol: 1e-6, ..Self::default() }
    }

    /// Fast, loose solve (weight initialisation use-case from §7).
    pub fn fast() -> Self {
        Self { max_sweeps: 10, tol: 1e-3, ..Self::default() }
    }

    /// Fluent construction:
    /// `SolveOptions::builder().tol(1e-6).threads(4).build()`.
    pub fn builder() -> SolveOptionsBuilder {
        SolveOptionsBuilder { opts: Self::default() }
    }
}

/// Builder for [`SolveOptions`]; starts from the defaults, every knob is
/// optional.
#[derive(Clone, Debug, Default)]
pub struct SolveOptionsBuilder {
    opts: SolveOptions,
}

impl SolveOptionsBuilder {
    pub fn max_sweeps(mut self, v: usize) -> Self {
        self.opts.max_sweeps = v;
        self
    }

    pub fn tol(mut self, v: f64) -> Self {
        self.opts.tol = v;
        self
    }

    pub fn order(mut self, v: ColumnOrder) -> Self {
        self.opts.order = v;
        self
    }

    pub fn thr(mut self, v: usize) -> Self {
        self.opts.thr = v;
        self
    }

    pub fn threads(mut self, v: usize) -> Self {
        self.opts.threads = v;
        self
    }

    pub fn check_every(mut self, v: usize) -> Self {
        self.opts.check_every = v;
        self
    }

    pub fn seed(mut self, v: u64) -> Self {
        self.opts.seed = v;
        self
    }

    pub fn probe(mut self, v: crate::obs::ProbeHandle) -> Self {
        self.opts.probe = v;
        self
    }

    pub fn cancel(mut self, v: crate::robust::CancelToken) -> Self {
        self.opts.cancel = v;
        self
    }

    pub fn build(self) -> SolveOptions {
        self.opts
    }
}

/// Why the solver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Relative residual went below `tol`.
    Converged,
    /// Residual stopped improving (hit the f32 floor / LS optimum).
    Stalled,
    /// Ran out of sweeps.
    MaxSweeps,
    /// Stopped early by a [`crate::robust::CancelToken`] (deadline expiry
    /// or explicit cancellation); `a`/`e` hold the best-so-far state.
    Cancelled,
    /// The residual norm became NaN/Inf — the iterate is numerical
    /// garbage (poisoned input or f32 overflow). Surfaced within one
    /// residual check instead of iterating to `max_sweeps`; callers map
    /// it to [`crate::api::SolverError::NumericalBreakdown`].
    Breakdown,
}

/// Solve outcome: coefficients, final residual, and the per-sweep history.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// The estimated coefficient vector (vars).
    pub a: Vec<f32>,
    /// Final residual e = y - X a (obs).
    pub e: Vec<f32>,
    /// Squared residual after each completed sweep.
    pub history: Vec<f64>,
    /// ||y||^2 for relative-residual reporting.
    pub y_norm_sq: f64,
    /// Number of completed sweeps.
    pub sweeps: usize,
    pub stop: StopReason,
}

impl SolveReport {
    /// Relative residual sqrt(sum e^2 / sum y^2); 0/0 counts as 0.
    pub fn rel_residual(&self) -> f64 {
        let r2 = blas1::sum_sq_f64(&self.e);
        if self.y_norm_sq == 0.0 {
            r2.sqrt()
        } else {
            (r2 / self.y_norm_sq).sqrt()
        }
    }

    /// True if the run ended by hitting the tolerance.
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }
}

/// Precompute 1/<x_j,x_j>, with zero columns mapped to 0 (they are skipped;
/// a zero column can never reduce the residual).
pub fn colnorms_inv(x: &crate::linalg::Mat) -> Vec<f32> {
    x.colnorms_sq()
        .iter()
        .map(|&v| if v > 0.0 { 1.0 / v } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    #[test]
    fn default_options_match_paper() {
        let o = SolveOptions::default();
        assert_eq!(o.thr, 50);
        assert_eq!(o.order, ColumnOrder::Cyclic);
    }

    #[test]
    fn colnorms_inv_zero_column() {
        let mut rng = Rng::seed(1);
        let mut x = Mat::randn(&mut rng, 10, 3);
        x.col_mut(1).fill(0.0);
        let cn = colnorms_inv(&x);
        assert!(cn[0] > 0.0);
        assert_eq!(cn[1], 0.0);
        assert!(cn[2] > 0.0);
    }

    #[test]
    fn builder_overrides_only_named_knobs() {
        let o = SolveOptions::builder().tol(1e-4).threads(4).thr(8).build();
        assert_eq!(o.tol, 1e-4);
        assert_eq!(o.threads, 4);
        assert_eq!(o.thr, 8);
        // Untouched knobs keep their defaults.
        let d = SolveOptions::default();
        assert_eq!(o.max_sweeps, d.max_sweeps);
        assert_eq!(o.order, d.order);
        assert_eq!(o.check_every, d.check_every);
        assert_eq!(o.seed, d.seed);
        assert!(!o.probe.is_enabled(), "probe defaults to disabled");
        assert!(!o.cancel.is_enabled(), "cancel defaults to disabled");
    }

    #[test]
    fn builder_attaches_cancel_token() {
        let token = crate::robust::CancelToken::manual();
        let o = SolveOptions::builder().cancel(token.clone()).build();
        assert!(o.cancel.is_enabled());
        assert!(!o.cancel.is_cancelled());
        token.cancel();
        assert!(o.cancel.is_cancelled(), "builder shares the token state");
    }

    #[test]
    fn builder_attaches_probe() {
        let probe = crate::obs::RingProbe::new(8);
        let o = SolveOptions::builder()
            .probe(crate::obs::ProbeHandle::new(probe))
            .build();
        assert!(o.probe.is_enabled());
        assert!(!SolveOptions::default().probe.is_enabled());
    }

    #[test]
    fn rel_residual_zero_y() {
        let rep = SolveReport {
            a: vec![],
            e: vec![0.0; 4],
            history: vec![],
            y_norm_sq: 0.0,
            sweeps: 0,
            stop: StopReason::Converged,
        };
        assert_eq!(rep.rel_residual(), 0.0);
    }
}
