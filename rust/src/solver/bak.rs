//! Algorithm 1 — SolveBak: sequential cyclic coordinate descent.
//!
//! The inner step for column j is
//!
//! ```text
//! da  = <x_j, e> / <x_j, x_j>
//! e  -= x_j * da
//! a_j += da
//! ```
//!
//! i.e. one `dot` + one `axpy` of length obs — O(obs*vars) per sweep, the
//! paper's headline complexity. The column slice is contiguous (col-major
//! [`Mat`]), so each step is two linear passes over one column.

use crate::linalg::{blas1, Mat};
use crate::util::rng::Rng;

use super::{colnorms_inv, ColumnOrder, SolveOptions, SolveReport, StopReason};

/// Solve x a ≈ y with Algorithm 1. See [`SolveOptions`] for the knobs.
pub fn solve_bak(x: &Mat, y: &[f32], opts: &SolveOptions) -> SolveReport {
    let (obs, vars) = x.shape();
    assert_eq!(y.len(), obs, "y length must equal obs");
    let cninv = colnorms_inv(x);
    let mut a = vec![0.0f32; vars];
    let mut e = y.to_vec();
    solve_bak_warm(x, &cninv, &mut a, &mut e, y, opts)
}

/// Warm-start variant: continues from caller-provided (a, e). The caller
/// must guarantee `e == y - X a` on entry (checked in debug builds).
pub fn solve_bak_warm(
    x: &Mat,
    cninv: &[f32],
    a: &mut Vec<f32>,
    e: &mut Vec<f32>,
    y: &[f32],
    opts: &SolveOptions,
) -> SolveReport {
    let vars = x.cols();
    debug_assert_eq!(a.len(), vars);
    debug_assert_eq!(e.len(), x.rows());
    #[cfg(debug_assertions)]
    {
        let check = crate::linalg::residual(x, y, a);
        for (c, g) in check.iter().zip(e.iter()) {
            debug_assert!((c - g).abs() < 1e-3, "warm start invariant e == y - Xa");
        }
    }

    let y_norm_sq = blas1::sum_sq_f64(y);
    let tol_sq = opts.tol * opts.tol * y_norm_sq;
    let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
    let mut rng = Rng::seed(opts.seed);
    let mut order: Vec<usize> = (0..vars).collect();
    let mut stop = StopReason::MaxSweeps;
    let mut sweeps = 0;
    let mut prev_r2 = f64::INFINITY;
    let t0 = std::time::Instant::now();

    for sweep in 0..opts.max_sweeps {
        if opts.order == ColumnOrder::Shuffled {
            rng.shuffle(&mut order);
        }
        for &j in &order {
            let cn = cninv[j];
            if cn == 0.0 {
                continue; // zero column
            }
            let da = blas1::cd_step(x.col(j), e, cn);
            a[j] += da;
        }
        sweeps = sweep + 1;
        let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
        if check_now || sweeps == opts.max_sweeps {
            let r2 = blas1::sum_sq_f64(e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, a, e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            // Stall detection: residual no longer improving (LS optimum or
            // the f32 floor) — continuing would only burn time.
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
    }

    SolveReport {
        a: std::mem::take(a),
        e: std::mem::take(e),
        history,
        y_norm_sq,
        sweeps,
        stop,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::residual;
    use crate::util::stats::{mape, rel_l2};

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (x, y, a)
    }

    #[test]
    fn tall_consistent_recovers_truth() {
        let (x, y, a_true) = planted(100, 400, 40);
        let rep = solve_bak(&x, &y, &SolveOptions::accurate());
        assert!(rep.converged(), "stop={:?} rel={}", rep.stop, rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3, "err={}", rel_l2(&rep.a, &a_true));
        // Accuracy comparable to Table 1's MAPE regime for f32.
        assert!(mape(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn square_system_solves() {
        // Square random systems are CD's worst case (rate ~ 1-1/cond^2);
        // run to stall and accept the f32-floor residual.
        let (x, y, a_true) = planted(101, 64, 64);
        let mut o = SolveOptions::default();
        o.max_sweeps = 30_000;
        o.tol = 1e-5;
        o.check_every = 10;
        let rep = solve_bak(&x, &y, &o);
        assert!(rep.rel_residual() < 1e-3, "rel={}", rep.rel_residual());
        assert!(rel_l2(&rep.a, &a_true) < 0.05, "err={}", rel_l2(&rep.a, &a_true));
    }

    #[test]
    fn wide_system_satisfies_equations() {
        let (x, y, _) = planted(102, 32, 128);
        let rep = solve_bak(&x, &y, &SolveOptions::accurate());
        assert!(rep.rel_residual() < 1e-5, "wide must interpolate");
    }

    #[test]
    fn inconsistent_tall_reaches_ls_optimum() {
        let mut rng = Rng::seed(103);
        let x = Mat::randn(&mut rng, 200, 10);
        let y: Vec<f32> = (0..200).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.max_sweeps = 2000;
        o.tol = 0.0; // run to stall
        let rep = solve_bak(&x, &y, &o);
        let a_qr = crate::baselines::qr::lstsq_qr(&x, &y).unwrap();
        assert!(rel_l2(&rep.a, &a_qr) < 1e-2, "err={}", rel_l2(&rep.a, &a_qr));
    }

    #[test]
    fn history_monotone_nonincreasing() {
        let (x, y, _) = planted(104, 100, 50);
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 50;
        let rep = solve_bak(&x, &y, &o);
        for w in rep.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "Theorem 1 violated: {w:?}");
        }
    }

    #[test]
    fn exit_invariant_e_equals_y_minus_xa() {
        let (x, y, _) = planted(105, 80, 30);
        let rep = solve_bak(&x, &y, &SolveOptions::default());
        let fresh = residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-3);
        }
    }

    #[test]
    fn tolerance_early_break_stops_early() {
        let (x, y, _) = planted(106, 300, 20);
        let mut loose = SolveOptions::default();
        loose.tol = 1e-2;
        loose.max_sweeps = 1000;
        let rep_loose = solve_bak(&x, &y, &loose);
        let mut tight = loose.clone();
        tight.tol = 1e-6;
        let rep_tight = solve_bak(&x, &y, &tight);
        assert!(rep_loose.sweeps < rep_tight.sweeps);
        assert!(rep_loose.converged() && rep_tight.converged());
    }

    #[test]
    fn shuffled_order_also_converges() {
        let (x, y, a_true) = planted(107, 200, 30);
        let mut o = SolveOptions::accurate();
        o.order = ColumnOrder::Shuffled;
        let rep = solve_bak(&x, &y, &o);
        assert!(rep.converged());
        assert!(rel_l2(&rep.a, &a_true) < 1e-3);
    }

    #[test]
    fn shuffled_is_deterministic_per_seed() {
        let (x, y, _) = planted(108, 100, 20);
        let mut o = SolveOptions::default();
        o.order = ColumnOrder::Shuffled;
        o.max_sweeps = 5;
        o.tol = 0.0;
        let r1 = solve_bak(&x, &y, &o);
        let r2 = solve_bak(&x, &y, &o);
        assert_eq!(r1.a, r2.a);
    }

    #[test]
    fn zero_column_ignored() {
        let mut rng = Rng::seed(109);
        let mut x = Mat::randn(&mut rng, 50, 8);
        x.col_mut(4).fill(0.0);
        let y: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let rep = solve_bak(&x, &y, &SolveOptions::default());
        assert_eq!(rep.a[4], 0.0);
        assert!(rep.a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let (x, _, _) = planted(110, 40, 10);
        let rep = solve_bak(&x, &[0.0; 40], &SolveOptions::default());
        assert!(rep.a.iter().all(|&v| v == 0.0));
        assert!(rep.converged());
        assert_eq!(rep.sweeps, 1);
    }

    #[test]
    fn single_column_solves_in_one_sweep() {
        let mut rng = Rng::seed(111);
        let x = Mat::randn(&mut rng, 100, 1);
        let y: Vec<f32> = x.col(0).iter().map(|&v| 2.5 * v).collect();
        let rep = solve_bak(&x, &y, &SolveOptions::default());
        assert!((rep.a[0] - 2.5).abs() < 1e-5);
        assert_eq!(rep.sweeps, 1);
    }

    #[test]
    fn check_every_reduces_history_density() {
        let (x, y, _) = planted(112, 100, 20);
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 20;
        o.check_every = 5;
        let rep = solve_bak(&x, &y, &o);
        assert!(rep.history.len() <= 5); // 20/5 + final
    }

    #[test]
    fn probe_sees_every_check_and_does_not_perturb_solve() {
        let (x, y, _) = planted(114, 100, 20);
        let probe = crate::obs::RingProbe::new(64);
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 10;
        o.probe = crate::obs::ProbeHandle::new(probe.clone());
        let rep = solve_bak(&x, &y, &o);
        let snap = probe.snapshot();
        assert_eq!(snap.len(), rep.history.len());
        for (p, &h) in snap.iter().zip(&rep.history) {
            assert!((p.residual_norm - h.sqrt()).abs() < 1e-12);
        }
        // Same solve without the probe is bit-identical.
        let mut o2 = o.clone();
        o2.probe = crate::obs::ProbeHandle::none();
        let rep2 = solve_bak(&x, &y, &o2);
        assert_eq!(rep.a, rep2.a);
    }

    #[test]
    fn cancel_token_stops_mid_run_with_best_so_far() {
        let (x, y, _) = planted(115, 100, 20);
        let token = crate::robust::CancelToken::manual();
        token.cancel(); // expired before the first residual check
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 1000;
        o.cancel = token;
        let rep = solve_bak(&x, &y, &o);
        assert_eq!(rep.stop, StopReason::Cancelled);
        assert_eq!(rep.sweeps, 1, "stops at the first check");
        // Best-so-far state still upholds e == y - X a.
        let fresh = residual(&x, &y, &rep.a);
        for (f, g) in fresh.iter().zip(&rep.e) {
            assert!((f - g).abs() < 1e-3);
        }
    }

    #[test]
    fn disabled_cancel_token_does_not_perturb_solve() {
        let (x, y, _) = planted(116, 100, 20);
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 10;
        let rep = solve_bak(&x, &y, &o);
        let mut armed = o.clone();
        armed.cancel = crate::robust::CancelToken::with_deadline_ms(600_000);
        let rep2 = solve_bak(&x, &y, &armed);
        assert_eq!(rep.a, rep2.a, "un-expired token is bit-identical");
        assert_eq!(rep2.stop, StopReason::MaxSweeps);
    }

    #[test]
    fn poisoned_input_breaks_down_within_one_check() {
        let (x, mut y, _) = planted(117, 100, 20);
        y[3] = f32::NAN;
        let mut o = SolveOptions::default();
        o.tol = 0.0;
        o.max_sweeps = 10_000;
        let rep = solve_bak(&x, &y, &o);
        assert_eq!(rep.stop, StopReason::Breakdown);
        assert_eq!(rep.sweeps, 1, "NaN must surface at the first check, not max_sweeps");
    }

    #[test]
    fn stall_detection_fires_on_ls_optimum() {
        let mut rng = Rng::seed(113);
        let x = Mat::randn(&mut rng, 60, 4);
        let y: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
        let mut o = SolveOptions::default();
        o.tol = 1e-30; // unreachable: inconsistent system
        o.max_sweeps = 100_000;
        let rep = solve_bak(&x, &y, &o);
        assert_eq!(rep.stop, StopReason::Stalled);
        assert!(rep.sweeps < 100_000);
    }
}
