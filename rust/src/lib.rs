//! # solvebak
//!
//! Production-grade reproduction of *"Algorithmic Solution for Non-Square,
//! Dense Systems of Linear Equations, with applications in Feature
//! Selection"* (Bakas, 2021) — the **SolveBak** / **SolveBakP** /
//! **SolveBakF** coordinate-action solvers — as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** Pallas kernels (`python/compile/kernels/`) implement the
//!   per-block coordinate-descent hot spot; validated against a pure-jnp
//!   oracle and lowered (interpret mode) into the L2 graphs.
//! * **L2** JAX graphs (`python/compile/model.py`) compose kernels into
//!   whole sweeps and are AOT-lowered to HLO-text artifacts at build time.
//! * **L3** this crate: native solver implementations, the baselines the
//!   paper benchmarks against, a PJRT runtime that executes the AOT
//!   artifacts, and a coordinator service that routes/batches solve
//!   requests. Python never runs at request time.
//!
//! ## Quickstart
//!
//! Every algorithm — the paper's solvers, the comparator baselines, and
//! the PJRT runtime — sits behind one [`api::Solver`] trait, addressed by
//! [`api::SolverKind`] and constructed from [`api::registry`]:
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::linalg::Mat;
//! use solvebak::solver::SolveOptions;
//! use solvebak::util::rng::Rng;
//!
//! let mut rng = Rng::seed(42);
//! let x = Mat::randn(&mut rng, 1000, 100);      // obs x vars
//! let a_true: Vec<f32> = (0..100).map(|i| i as f32 * 0.01).collect();
//! let y = x.matvec(&a_true);
//!
//! let problem = Problem::new(&x, &y).expect("shapes validated");
//! let opts = SolveOptions::builder().max_sweeps(200).tol(1e-6).build();
//! let solver = solver_for(SolverKind::Bak).expect("registered");
//! let report = solver.solve(&problem, &opts).expect("typed errors, no panics");
//! assert!(report.rel_residual() < 1e-4);
//! ```
//!
//! The original free functions (`solver::solve_bak`,
//! `baselines::lstsq_qr`, …) remain as stable thin wrappers around the
//! same implementations. See the [`api`] module docs for the capability
//! matrix, `DESIGN.md` for the system inventory, and `EXPERIMENTS.md` for
//! the paper-vs-measured record.
//!
//! ## Sparse systems
//!
//! Algorithm 1's inner step touches one column, so on sparse data a sweep
//! is O(nnz), not O(obs·vars). Build a matrix from COO triplets
//! ([`sparse::CooBuilder`]), lower it to compressed-column storage
//! ([`sparse::CscMat`]), and solve through the same [`api::Solver`]
//! surface via [`api::Problem::new_sparse`]:
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::solver::SolveOptions;
//! use solvebak::sparse::CooBuilder;
//!
//! let mut coo = CooBuilder::new(4, 2);     // 4 obs x 2 vars
//! coo.push(0, 0, 1.0);
//! coo.push(2, 0, -2.0);
//! coo.push(1, 1, 3.0);
//! let x = coo.to_csc();                    // O(nnz log nnz) compression
//! let y = x.matvec(&[2.0, -1.0]);          // planted solution
//!
//! let problem = Problem::new_sparse(&x, &y).expect("validated");
//! let solver = solver_for(SolverKind::Bak).expect("registered");
//! let report = solver.solve(&problem, &SolveOptions::default()).expect("solves");
//! assert!(report.rel_residual() < 1e-4);
//! ```
//!
//! `bak`, `bak_par`, `bakp`, `kaczmarz`, `kaczmarz_par`, and `cgls` run
//! sparse problems natively (capability flag `supports_sparse`); every
//! other backend transparently densifies with a logged warning, and the
//! coordinator counts those events in its `densified_jobs` metric. Over
//! the wire, the coordinator accepts
//! `{"x_coo": {"rows": [...], "cols": [...], "vals": [...]}}` in place of
//! the dense `"x"` array, and the CLI exposes the workload class via
//! `solvebak solve --sparse --density 0.01`.
//!
//! ## Parallel execution
//!
//! The [`parallel`] module is the crate's std-only threading layer — a
//! worker pool ([`parallel::Executor`]: panic isolation per job, graceful
//! drain-on-shutdown, busy/inflight gauges) plus scoped fork-join helpers
//! — and the block-parallel solver variants built on it:
//!
//! * `bak_par` splits the columns into `threads` blocks, runs paper-style
//!   inner sweeps per block concurrently, and merges every sweep
//!   (additive coefficient merge + row-parallel residual rebuild).
//! * `kaczmarz_par` splits the rows, projects per block, and merges by
//!   norm-weighted averaging (parallel RK à la Fliege 2012).
//! * [`parallel::solve_bak_multi_par`] chunks a batch of right-hand sides
//!   across threads while sharing one column-norm precompute.
//!
//! All three are deterministic for a fixed `(seed, threads)` — block
//! structure and RNG streams key off the work item, never the OS worker —
//! and `threads = 1` with the default cyclic column order reduces the BAK
//! variants to the serial algorithms bit-for-bit. Select them like any
//! other backend and set
//! [`solver::SolveOptions::threads`]:
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::linalg::Mat;
//! use solvebak::solver::SolveOptions;
//! use solvebak::util::rng::Rng;
//!
//! let mut rng = Rng::seed(42);
//! let x = Mat::randn(&mut rng, 100_000, 256);
//! let a_true: Vec<f32> = (0..256).map(|i| i as f32 * 0.01).collect();
//! let y = x.matvec(&a_true);
//! let problem = Problem::new(&x, &y).expect("validated");
//!
//! let opts = SolveOptions::builder()
//!     .threads(solvebak::parallel::default_threads()) // PALLAS_THREADS-aware
//!     .tol(1e-6)
//!     .build();
//! let solver = solver_for(SolverKind::BakPar).expect("registered");
//! let report = solver.solve(&problem, &opts).expect("solves");
//! assert!(report.rel_residual() < 1e-4);
//! ```
//!
//! From the CLI the same knob is `--threads N` (default: `PALLAS_THREADS`,
//! else the machine's parallelism), e.g.
//! `solvebak solve --obs 1e6 --vars 200 --backend bak_par --threads 8`;
//! the coordinator sizes its worker pool the same way (`--workers`). The
//! router prefers the parallel variants automatically when a request asks
//! for `threads > 1`.
//!
//! ## Out-of-core streaming
//!
//! Algorithm 1 walks the matrix one column at a time, so X never needs to
//! be resident: the [`stream`] module stores it as a chunked on-disk file
//! (`.sbck`: versioned header + f32-LE column-major chunks, see
//! [`stream::format`]) and a prefetch thread double-buffers chunks into a
//! pool capped at a byte budget while the solver consumes the previous
//! one. [`stream::solve_bak_stream`], [`stream::solve_kaczmarz_stream`],
//! and [`stream::solve_bak_multi_stream`] are bit-identical to their
//! in-memory counterparts for the same seed — only the residency changes:
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::linalg::Mat;
//! use solvebak::solver::SolveOptions;
//! use solvebak::stream::{write_chunked_dense, StreamedMatrix};
//! use solvebak::util::rng::Rng;
//! use std::path::Path;
//!
//! // Convert once (or out-of-core via `stream::write_chunked_with`, or
//! // from the shell: `solvebak convert --obs 1e6 --vars 200 --out x.sbck`).
//! let mut rng = Rng::seed(42);
//! let x = Mat::randn(&mut rng, 10_000, 64);
//! let y = x.matvec(&vec![0.5; 64]);
//! write_chunked_dense(&x, 16, Path::new("x.sbck")).expect("convert");
//!
//! // Solve with only `mem_budget` bytes of X resident at a time.
//! let sm = StreamedMatrix::open("x.sbck").expect("header validated")
//!     .with_budget(8 << 20);
//! let problem = Problem::new_streamed(&sm, &y).expect("validated");
//! let solver = solver_for(SolverKind::Bak).expect("registered");
//! let report = solver.solve(&problem, &SolveOptions::default()).expect("solves");
//! assert!(report.rel_residual() < 1e-4);
//! ```
//!
//! `bak`, `bak_multi`, and `kaczmarz` run file-backed problems natively
//! (capability flag `supports_streaming`); any other backend returns a
//! typed [`SolverError::Unavailable`] instead of silently loading the file
//! into RAM — streamed jobs are never densified. The coordinator accepts
//! `{"x_path": "x.sbck", "mem_budget": 8388608}` over the wire (routing
//! `auto` to BAK) and exports `stream_chunks_read` / `stream_bytes_read` /
//! `stream_buffer_stalls` metrics; the CLI front-end is
//! `solvebak solve --x-file x.sbck --mem-budget 8388608`. The CI
//! `stream-smoke` job holds the acceptance bar: a 96 MiB matrix solved
//! under an 8 MiB budget with peak RSS checked against budget + slack.
//!
//! ## Observability
//!
//! The [`obs`] module makes the two things the paper advertises —
//! controllable accuracy and O(mn) runtime — measurable in production:
//!
//! * **Convergence probes.** Every iterative solver (dense, sparse,
//!   parallel, and streaming BAK/Kaczmarz/CGLS loops) calls an optional
//!   [`obs::SolveProbe`] once per residual check with
//!   `(sweep, residual_norm, elapsed_ns)`. The probe rides inside
//!   [`solver::SolveOptions::probe`]; the disabled default costs one
//!   branch per sweep — no allocation, no clock read. See the
//!   capability-matrix `probe` column in [`api`] for which backends
//!   report (the direct methods `qr`/`cholesky`/`gauss` and the bucketed
//!   `pjrt` runtime have no per-sweep residual to report).
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::linalg::Mat;
//! use solvebak::obs::{ProbeHandle, RingProbe};
//! use solvebak::solver::SolveOptions;
//! use solvebak::util::rng::Rng;
//!
//! let mut rng = Rng::seed(42);
//! let x = Mat::randn(&mut rng, 1000, 100);
//! let y = x.matvec(&vec![0.5; 100]);
//! let problem = Problem::new(&x, &y).expect("validated");
//!
//! let probe = RingProbe::new(64); // <= 64 downsampled points
//! let opts = SolveOptions::builder()
//!     .tol(1e-6)
//!     .probe(ProbeHandle::new(probe.clone()))
//!     .build();
//! solver_for(SolverKind::Bak).unwrap().solve(&problem, &opts).unwrap();
//! for p in probe.snapshot() {
//!     println!("sweep {} residual {}", p.sweep, p.residual_norm);
//! }
//! ```
//!
//! * **Spans & traces.** A request submitted to the coordinator with
//!   `"trace": true` gets a process-unique trace id and a per-stage span
//!   timeline (`queue_wait`, `route`, `solve` with `densify`/`stream_io`
//!   children, `merge`), returned in the response under `"telemetry"`
//!   together with the downsampled residual trajectory, and retained in a
//!   bounded ring served by `{"cmd":"traces"}`:
//!
//! ```text
//! $ echo '{"id":1,"obs":2,"vars":2,"x":[1,0,0,1],"y":[2,3],"trace":true}' | nc 127.0.0.1 7447
//! {"ok":true,...,"telemetry":{"trace_id":1,"spans":[...],"trajectory":[...]}}
//! ```
//!
//! * **Metrics exposition & the live dashboard.** `{"cmd":"metrics"}`
//!   returns the JSON counters; `{"cmd":"metrics_prom"}` returns the same
//!   registry in Prometheus text exposition format v0.0.4 (counters,
//!   gauges, cumulative histogram `_bucket`/`_sum`/`_count` series) ready
//!   to scrape; `solvebak stats --addr 127.0.0.1:7447 --interval 1` polls
//!   a running coordinator and prints a one-line-per-poll dashboard
//!   (req/s, p50/p99 latency, queue depth, busy workers, stream stalls).
//!   Set `PALLAS_LOG_FORMAT=json` to switch [`util::log`] to structured
//!   one-object-per-line output with optional `trace_id` correlation.
//!
//! ## Robustness
//!
//! The [`robust`] module keeps the service answering under pressure. The
//! BAK family's accuracy is "straightforwardly controlled" by the sweep
//! budget, so a partial answer is always available — the robustness layer
//! turns that into deadlines, admission control, and graceful
//! degradation:
//!
//! * **Deadlines & cancellation.** A [`robust::CancelToken`] rides inside
//!   [`solver::SolveOptions::cancel`] and is polled at every residual
//!   check (the same hook points as the convergence probe; one branch
//!   when disabled, so undeadlined solves stay bit-identical). Over the
//!   wire, `"deadline_ms"` arms the token when the request is admitted —
//!   queue wait spends the same budget — and an expired job stops
//!   mid-sweep, returning [`SolverError::DeadlineExceeded`] with the
//!   best-so-far coefficients and achieved residual:
//!
//! ```no_run
//! use solvebak::api::{solver_for, Problem, SolverKind};
//! use solvebak::linalg::Mat;
//! use solvebak::robust::CancelToken;
//! use solvebak::solver::{SolveOptions, StopReason};
//! use solvebak::util::rng::Rng;
//!
//! let mut rng = Rng::seed(42);
//! let x = Mat::randn(&mut rng, 100_000, 512);
//! let y = x.matvec(&vec![0.5; 512]);
//! let problem = Problem::new(&x, &y).expect("validated");
//!
//! // Give the solve 50 ms; whatever it reached by then comes back.
//! let opts = SolveOptions::builder()
//!     .max_sweeps(10_000)
//!     .cancel(CancelToken::with_deadline_ms(50))
//!     .build();
//! let report = solver_for(SolverKind::Bak).unwrap().solve(&problem, &opts).unwrap();
//! if report.stop == StopReason::Cancelled {
//!     println!("deadline hit after {} sweeps, residual {}",
//!              report.sweeps, report.rel_residual());
//! }
//! ```
//!
//! * **Admission control & load-shedding.** `serve-tcp --max-inflight N
//!   --max-queue-wait-ms M` puts a [`robust::AdmissionGate`] in front of
//!   the job queue: saturated requests get an immediate structured
//!   `{"error_kind":"overloaded","retry_after_ms":...}` reply instead of
//!   queueing forever, and `--degraded-sweeps K` answers them with a
//!   reduced-sweep BAK solve (`"degraded":true`) instead of rejecting.
//! * **Client retries.** The [`client`] module's
//!   [`client::RetryPolicy`] (jittered exponential backoff, budget-capped,
//!   honouring `retry_after_ms`) backs a small [`client::Client`] used by
//!   the CLI and the stats dashboard.
//! * **Fault injection.** A [`robust::FaultPlan`]
//!   (`PALLAS_FAULTS=worker_panic_every=7,slow_read_ms=50,...` or the TCP
//!   `{"cmd":"faults","plan":"..."}` command) injects worker panics, slow
//!   prefetch reads, and scheduler stalls; CI's `chaos-smoke` job uses it
//!   to prove every client still gets a structured reply. Metrics:
//!   `jobs_shed`, `jobs_deadline_exceeded`, `retries_attempted`,
//!   `degraded_solves`.
//!
//! The wire protocol itself is versioned (`"v": 1`, `{"cmd":"hello"}`
//! capability discovery, structured `error_kind: "unsupported"` for
//! unknown commands/fields) and documented in `PROTOCOL.md`.
//!
//! ## Durability & self-healing
//!
//! The same probe points that power observability and deadlines also make
//! solves durable and numerically self-healing (protocol v1.1 — additive
//! fields, `"v"` stays 1; see `PROTOCOL.md`):
//!
//! * **Checkpoint/resume.** A request carrying `"job_id"` on a server
//!   started with `--journal-dir DIR` is journalled: a
//!   [`robust::CheckpointProbe`] writes a versioned, CRC-sealed `.ckpt`
//!   snapshot ([`robust::Checkpoint`]) every `--checkpoint-every` sweeps,
//!   atomically (temp file + rename). Kill the process mid-solve,
//!   restart, re-submit the same `job_id`, and the solve warm-starts from
//!   the snapshot via [`api::Problem::with_warm_state`] — bit-identical
//!   to an uninterrupted run, because the checkpoint stores the
//!   maintained residual `e` alongside the iterate `a` instead of
//!   recomputing it. The reply carries `"resume": true`; a deadline-cut
//!   durable solve persists its best-so-far state so the retry resumes
//!   rather than starting over. A checkpoint whose solver, seed, or shape
//!   does not match is ignored (cold start), and the journal entry is
//!   removed once the job completes.
//! * **Chunk integrity.** `.sbck` files are format v2: every chunk is
//!   sealed with a CRC32 word, verified on every read (sync passes and
//!   the prefetch pipeline alike). A flipped bit surfaces as
//!   [`SolverError::CorruptData`] with the chunk index and both CRCs —
//!   never silently wrong math. v1 files (no checksums) remain readable.
//!   The `corrupt_chunk_every` fault knob injects exactly this damage so
//!   CI's `recovery-smoke` job can prove the detection path.
//! * **Numerical-health watchdog.** A [`robust::Watchdog`] rides the
//!   probe and trips on NaN/Inf residuals, sustained divergence, or
//!   stagnation, aborting the solve through its [`robust::CancelToken`].
//!   Without escalation the job answers
//!   `{"error_kind": "numerical_breakdown", "detail": ..., "sweeps": N}`;
//!   with `"escalate": true` the coordinator retries up the backend
//!   ladder (BAK → CGLS → QR) and the reply names the survivor in
//!   `"escalated_to"`. Metrics: `escalations`, `checkpoints_written`,
//!   `resumes`, `corrupt_chunks`.
//!
//! ## Distributed solving
//!
//! The block-parallel pair shards across *processes* the same way it
//! shards across threads: between sync points the per-block work of
//! `kaczmarz_par` (row blocks) and `bak_par` (column blocks) is
//! independent, and only the O(obs)/O(vars) sync vectors move. The
//! [`cluster`] module runs that scheme over an additive extension of the
//! wire protocol (v1.2 — `join`/`heartbeat`/`shard_solve`, `"v"` stays 1;
//! see `PROTOCOL.md` §cluster): a [`cluster::ClusterDriver`] inside the
//! coordinator keeps all global solver state, farms the per-sweep block
//! closures out to [`cluster::WorkerCore`] processes, and merges with the
//! same f64 mass-weighted fold the in-process schedulers use. For a fixed
//! `(seed, shards)` the clustered result is **bit-identical** to
//! [`parallel::solve_kaczmarz_par`] / [`parallel::solve_bak_par`] with
//! `threads = shards` — RNG streams key off `(seed, sweep, shard)`, never
//! off which worker ran the shard, so even a mid-solve worker loss (the
//! survivors absorb the dead worker's shards, warm-started from the last
//! synced iterate, and the reply carries `"resharded": true`) leaves the
//! answer unchanged. Two terminals:
//!
//! ```text
//! $ solvebak serve-worker --port 7450 &
//! $ solvebak serve-worker --port 7451 &
//! $ solvebak serve-tcp --port 7452 --cluster \
//!       --workers-addrs 127.0.0.1:7450,127.0.0.1:7451 --shards 4
//! $ echo '{"id":1,"obs":3,"vars":2,"backend":"kaczmarz_par","threads":4,
//!          "x":[1,0,0,0,1,0],"y":[2,3,0]}' | nc 127.0.0.1 7452
//! {"ok":true,...}
//! ```
//!
//! `hello` advertises the per-backend `supports_sharding` capability flag
//! (true exactly for `kaczmarz_par`/`bak_par`) plus the server's command
//! list; workers answering `overloaded` feed the coordinator's
//! [`client::RetryPolicy`] backoff, per-shard deadlines derive from the
//! job's `deadline_ms`, and the metrics registry exports
//! `cluster_workers`, `shards_dispatched`, `reshards`, and `sync_rounds`.

pub mod util;
pub mod obs;
pub mod linalg;
pub mod sparse;
pub mod baselines;
pub mod solver;
pub mod stream;
pub mod parallel;
pub mod robust;
pub mod api;
pub mod runtime;
pub mod coordinator;
pub mod cluster;
pub mod client;
pub mod bench;
pub mod cli;

pub use api::{Capabilities, MatrixRef, Problem, Solver, SolverError, SolverKind};

/// Crate version string (matches Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
