//! Re-export of the crate-wide bounded MPMC queue.
//!
//! The queue started life here as the coordinator's private job queue; the
//! parallel execution layer ([`crate::parallel`]) now owns it, because the
//! same injector backs both the coordinator's submit path and the generic
//! [`crate::parallel::Executor`] worker pool. Existing
//! `coordinator::queue::BoundedQueue` callers keep compiling unchanged.

pub use crate::parallel::queue::{BoundedQueue, Closed};
