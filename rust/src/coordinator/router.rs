//! Backend routing policy.
//!
//! Mirrors a serving router's model-selection logic: given the problem
//! shape and the request's hint, decide which solver runs. The policy
//! encodes the paper's own empirical guidance (§7): BAK/BAKP win on
//! strongly non-square systems; direct methods win on square ones; PJRT
//! buckets serve shapes covered by the artifact menu.

use crate::runtime::{ArtifactKind, Manifest};

use super::request::Backend;

/// The routing decision with its rationale (exposed for observability).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub backend: Backend,
    pub reason: &'static str,
}

/// Aspect-ratio threshold above which a system counts as "strongly
/// non-square" (tall or wide) — where Table 1 shows the BAK family
/// winning by 1-3 orders of magnitude.
pub const NONSQUARE_RATIO: f64 = 4.0;

/// Decide a backend for an (obs, vars) problem.
///
/// * Explicit hints are honoured verbatim (except Pjrt with no fitting
///   artifact, which falls back to native BAKP).
/// * Auto: square-ish -> QR (direct methods won in §7); tall/wide with a
///   fitting artifact -> Pjrt; otherwise BAKP for parallel-friendly
///   shapes, BAK for small ones.
pub fn route(
    backend: Backend,
    obs: usize,
    vars: usize,
    manifest: Option<&Manifest>,
) -> RouteDecision {
    let has_artifact = manifest
        .map(|m| m.route(ArtifactKind::BakpSweep, obs, vars).is_some())
        .unwrap_or(false);
    match backend {
        Backend::Pjrt if !has_artifact => RouteDecision {
            backend: Backend::Bakp,
            reason: "pjrt requested but no artifact bucket fits; native bakp fallback",
        },
        Backend::Auto => {
            let ratio = if vars == 0 {
                1.0
            } else {
                (obs as f64 / vars as f64).max(vars as f64 / obs as f64)
            };
            if ratio < NONSQUARE_RATIO {
                RouteDecision {
                    backend: Backend::Qr,
                    reason: "square-ish system: direct QR wins (paper §7)",
                }
            } else if has_artifact {
                RouteDecision {
                    backend: Backend::Pjrt,
                    reason: "non-square + artifact bucket available",
                }
            } else if obs * vars >= 1 << 20 {
                RouteDecision {
                    backend: Backend::Bakp,
                    reason: "large non-square: block-parallel sweeps",
                }
            } else {
                RouteDecision { backend: Backend::Bak, reason: "small non-square: sequential CD" }
            }
        }
        b => RouteDecision { backend: b, reason: "explicit backend hint" },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny_manifest() -> Manifest {
        Manifest::parse(
            r#"{"version":1,"artifacts":[
                {"name":"bakp_sweep_256x64","kind":"bakp_sweep","obs":256,
                 "vars":64,"width":32,"dtype":"f32",
                 "file":"bakp_sweep_256x64.hlo.txt",
                 "inputs":["x","cninv","a","e"],"outputs":["a","e","r2"]}]}"#,
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    #[test]
    fn explicit_hint_honoured() {
        let d = route(Backend::Qr, 10_000, 10, None);
        assert_eq!(d.backend, Backend::Qr);
        let d = route(Backend::Bak, 100, 100, None);
        assert_eq!(d.backend, Backend::Bak);
    }

    #[test]
    fn auto_square_goes_qr() {
        let d = route(Backend::Auto, 128, 100, None);
        assert_eq!(d.backend, Backend::Qr);
    }

    #[test]
    fn auto_tall_small_goes_bak() {
        let d = route(Backend::Auto, 4000, 10, None);
        assert_eq!(d.backend, Backend::Bak);
    }

    #[test]
    fn auto_tall_large_goes_bakp() {
        let d = route(Backend::Auto, 2_000_000, 100, None);
        assert_eq!(d.backend, Backend::Bakp);
    }

    #[test]
    fn auto_prefers_pjrt_when_bucket_fits() {
        let m = tiny_manifest();
        let d = route(Backend::Auto, 200, 40, Some(&m));
        assert_eq!(d.backend, Backend::Pjrt);
    }

    #[test]
    fn pjrt_hint_falls_back_without_bucket() {
        let m = tiny_manifest();
        let d = route(Backend::Pjrt, 100_000, 500, Some(&m));
        assert_eq!(d.backend, Backend::Bakp);
        let d = route(Backend::Pjrt, 100, 100, None);
        assert_eq!(d.backend, Backend::Bakp);
    }

    #[test]
    fn wide_counts_as_nonsquare() {
        let d = route(Backend::Auto, 10, 4000, None);
        assert_ne!(d.backend, Backend::Qr);
    }
}
