//! Backend routing policy.
//!
//! Mirrors a serving router's model-selection logic: given the problem
//! shape and the request's hint, decide which solver runs. The policy
//! encodes the paper's own empirical guidance (§7): BAK/BAKP win on
//! strongly non-square systems; direct methods win on square ones; PJRT
//! buckets serve shapes covered by the artifact menu. Hints are checked
//! against the hinted solver's [`crate::api::Capabilities`] — a solver
//! that cannot handle the shape (Gaussian elimination on a tall system,
//! Cholesky on a wide one) falls back to QR instead of failing downstream.

use crate::api::SolverKind;
use crate::runtime::{ArtifactKind, Manifest};

/// The routing decision with its rationale (exposed for observability).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub backend: SolverKind,
    pub reason: &'static str,
}

/// Aspect-ratio threshold above which a system counts as "strongly
/// non-square" (tall or wide) — where Table 1 shows the BAK family
/// winning by 1-3 orders of magnitude.
pub const NONSQUARE_RATIO: f64 = 4.0;

/// Decide a backend for an (obs, vars) problem.
///
/// * Explicit hints are honoured when the hinted solver's capabilities
///   cover the shape; otherwise QR (which handles tall and wide) runs.
///   Pjrt with no fitting artifact falls back to native BAKP (BAK_PAR
///   when the request asks for threads). A dense-only hint on a sparse
///   job is still honoured — the executor densifies and counts it —
///   because an explicit hint is a contract.
/// * Auto + dense: square-ish -> QR (direct methods won in §7); tall/wide
///   with a fitting artifact -> Pjrt; otherwise BAK_PAR when the request
///   asks for `threads > 1` (block-parallel whole sweeps), BAKP for
///   large single-thread shapes, BAK for small ones.
/// * Auto + sparse: native O(nnz) CD — block-parallel BAK_PAR when
///   `threads > 1`, sequential BAK otherwise. Densifying for QR would
///   forfeit the O(nnz) win the sparse representation exists for.
/// * Auto + streamed (file-backed matrix): BAK, the streaming-native
///   sequential CD — regardless of threads or artifacts, since only the
///   serial trio (bak, kaczmarz, bak_multi) can consume a sequential
///   chunk stream. A hinted backend stays honoured (hints are contracts);
///   non-streaming backends then return a typed `SolverError` from the
///   [`crate::api::backends`] layer instead of OOMing.
pub fn route(
    backend: SolverKind,
    obs: usize,
    vars: usize,
    sparse: bool,
    streamed: bool,
    threads: usize,
    manifest: Option<&Manifest>,
) -> RouteDecision {
    let has_artifact = manifest
        .map(|m| m.route(ArtifactKind::BakpSweep, obs, vars).is_some())
        .unwrap_or(false);
    let parallel = threads > 1;
    match backend {
        SolverKind::Pjrt if !has_artifact && parallel => RouteDecision {
            backend: SolverKind::BakPar,
            reason: "pjrt requested but no artifact bucket fits; threaded bak_par fallback",
        },
        SolverKind::Pjrt if !has_artifact => RouteDecision {
            backend: SolverKind::Bakp,
            reason: "pjrt requested but no artifact bucket fits; native bakp fallback",
        },
        SolverKind::Auto if streamed => RouteDecision {
            backend: SolverKind::Bak,
            reason: "file-backed system: streaming-native sequential CD",
        },
        SolverKind::Auto if sparse && parallel => RouteDecision {
            backend: SolverKind::BakPar,
            reason: "sparse system + threads: block-parallel CD on native O(nnz) path",
        },
        SolverKind::Auto if sparse => {
            // Sequential BAK: per sweep both sparse CD variants cost
            // O(nnz), and with one thread the block variants buy nothing
            // — so BAK dominates regardless of the dense cell count,
            // which says nothing about actual sparse work anyway.
            RouteDecision {
                backend: SolverKind::Bak,
                reason: "sparse system: sequential CD on native O(nnz) path",
            }
        }
        SolverKind::Auto => {
            let ratio = if vars == 0 {
                1.0
            } else {
                (obs as f64 / vars as f64).max(vars as f64 / obs as f64)
            };
            if ratio < NONSQUARE_RATIO {
                RouteDecision {
                    backend: SolverKind::Qr,
                    reason: "square-ish system: direct QR wins (paper §7)",
                }
            } else if has_artifact {
                RouteDecision {
                    backend: SolverKind::Pjrt,
                    reason: "non-square + artifact bucket available",
                }
            } else if parallel {
                RouteDecision {
                    backend: SolverKind::BakPar,
                    reason: "non-square + threads: block-parallel whole sweeps",
                }
            } else if obs * vars >= 1 << 20 {
                RouteDecision {
                    backend: SolverKind::Bakp,
                    reason: "large non-square: block-parallel sweeps",
                }
            } else {
                RouteDecision {
                    backend: SolverKind::Bak,
                    reason: "small non-square: sequential CD",
                }
            }
        }
        hint => {
            match hint.capabilities() {
                Some(c) if c.needs_square && obs != vars => RouteDecision {
                    backend: SolverKind::Qr,
                    reason: "hinted solver needs a square system; QR fallback",
                },
                Some(c) if !c.supports_wide && vars > obs => RouteDecision {
                    backend: SolverKind::Qr,
                    reason: "hinted solver cannot handle wide systems; QR fallback",
                },
                _ => RouteDecision { backend: hint, reason: "explicit backend hint" },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny_manifest() -> Manifest {
        Manifest::parse(
            r#"{"version":1,"artifacts":[
                {"name":"bakp_sweep_256x64","kind":"bakp_sweep","obs":256,
                 "vars":64,"width":32,"dtype":"f32",
                 "file":"bakp_sweep_256x64.hlo.txt",
                 "inputs":["x","cninv","a","e"],"outputs":["a","e","r2"]}]}"#,
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    #[test]
    fn explicit_hint_honoured() {
        let d = route(SolverKind::Qr, 10_000, 10, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        let d = route(SolverKind::Bak, 100, 100, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bak);
        let d = route(SolverKind::Cgls, 500, 20, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Cgls);
        // A serial hint stays honoured even when threads are requested —
        // an explicit hint is a contract.
        let d = route(SolverKind::Bak, 10_000, 10, false, false, 8, None);
        assert_eq!(d.backend, SolverKind::Bak);
    }

    #[test]
    fn auto_square_goes_qr() {
        let d = route(SolverKind::Auto, 128, 100, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        // Direct methods don't thread; square-ish stays QR regardless.
        let d = route(SolverKind::Auto, 128, 100, false, false, 8, None);
        assert_eq!(d.backend, SolverKind::Qr);
    }

    #[test]
    fn auto_tall_small_goes_bak() {
        let d = route(SolverKind::Auto, 4000, 10, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bak);
    }

    #[test]
    fn auto_tall_large_goes_bakp() {
        let d = route(SolverKind::Auto, 2_000_000, 100, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bakp);
    }

    #[test]
    fn auto_with_threads_prefers_bak_par() {
        let d = route(SolverKind::Auto, 2_000_000, 100, false, false, 8, None);
        assert_eq!(d.backend, SolverKind::BakPar);
        let d = route(SolverKind::Auto, 4000, 10, false, false, 2, None);
        assert_eq!(d.backend, SolverKind::BakPar);
    }

    #[test]
    fn auto_prefers_pjrt_when_bucket_fits() {
        let m = tiny_manifest();
        let d = route(SolverKind::Auto, 200, 40, false, false, 1, Some(&m));
        assert_eq!(d.backend, SolverKind::Pjrt);
    }

    #[test]
    fn pjrt_hint_falls_back_without_bucket() {
        let m = tiny_manifest();
        let d = route(SolverKind::Pjrt, 100_000, 500, false, false, 1, Some(&m));
        assert_eq!(d.backend, SolverKind::Bakp);
        let d = route(SolverKind::Pjrt, 100, 100, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bakp);
        // ...and to the threaded variant when the request asks for it.
        let d = route(SolverKind::Pjrt, 100, 100, false, false, 4, None);
        assert_eq!(d.backend, SolverKind::BakPar);
    }

    #[test]
    fn wide_counts_as_nonsquare() {
        let d = route(SolverKind::Auto, 10, 4000, false, false, 1, None);
        assert_ne!(d.backend, SolverKind::Qr);
    }

    #[test]
    fn capability_mismatch_falls_back_to_qr() {
        // Gaussian elimination on a tall system: needs_square.
        let d = route(SolverKind::Gauss, 400, 20, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        // Cholesky on a wide system: !supports_wide.
        let d = route(SolverKind::Cholesky, 20, 400, false, false, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        // Both are honoured on shapes they handle.
        assert_eq!(route(SolverKind::Gauss, 64, 64, false, false, 1, None).backend, SolverKind::Gauss);
        assert_eq!(
            route(SolverKind::Cholesky, 400, 20, false, false, 1, None).backend,
            SolverKind::Cholesky
        );
    }

    #[test]
    fn auto_sparse_never_picks_a_densifying_backend() {
        // Square-ish sparse would have gone to QR; the sparse route keeps
        // it on the native O(nnz) solver instead, at every scale.
        let d = route(SolverKind::Auto, 128, 100, true, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bak);
        let d = route(SolverKind::Auto, 100_000, 256, true, false, 1, None);
        assert_eq!(d.backend, SolverKind::Bak);
        // ...even when a PJRT bucket would fit the shape.
        let m = tiny_manifest();
        let d = route(SolverKind::Auto, 200, 40, true, false, 1, Some(&m));
        assert_eq!(d.backend, SolverKind::Bak);
        // Threads keep it sparse-native too, on the block-parallel path.
        let d = route(SolverKind::Auto, 200, 40, true, false, 8, Some(&m));
        assert_eq!(d.backend, SolverKind::BakPar);
    }

    #[test]
    fn auto_streamed_routes_to_bak() {
        // File-backed jobs always land on the streaming-native sequential
        // CD, regardless of shape, threads, or available artifacts.
        let d = route(SolverKind::Auto, 128, 100, false, true, 1, None);
        assert_eq!(d.backend, SolverKind::Bak);
        let d = route(SolverKind::Auto, 2_000_000, 100, false, true, 8, None);
        assert_eq!(d.backend, SolverKind::Bak);
        let m = tiny_manifest();
        let d = route(SolverKind::Auto, 200, 40, false, true, 1, Some(&m));
        assert_eq!(d.backend, SolverKind::Bak);
    }

    #[test]
    fn explicit_hint_kept_on_streamed_jobs() {
        // Hints are contracts even for backends with no streaming path —
        // those return a typed SolverError from the backends layer.
        let d = route(SolverKind::Qr, 10_000, 10, false, true, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        let d = route(SolverKind::Kaczmarz, 10_000, 10, false, true, 1, None);
        assert_eq!(d.backend, SolverKind::Kaczmarz);
    }

    #[test]
    fn explicit_dense_only_hint_kept_on_sparse_jobs() {
        // The executor densifies (and counts densified_jobs); routing
        // honours the contract.
        let d = route(SolverKind::Qr, 4096, 1024, true, false, 1, None);
        assert_eq!(d.backend, SolverKind::Qr);
        assert_eq!(
            route(SolverKind::Kaczmarz, 400, 20, true, false, 1, None).backend,
            SolverKind::Kaczmarz
        );
    }
}
