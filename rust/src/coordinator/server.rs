//! TCP front-end for the coordinator: newline-delimited JSON protocol.
//!
//! **Protocol v1** (the full contract lives in `PROTOCOL.md` at the repo
//! root): requests may carry `"v": 1` — an absent `"v"` means v1 — and
//! the server rejects other versions, unknown commands, and unknown
//! top-level request fields with `error_kind: "unsupported"` instead of
//! guessing. `{"cmd": "hello"}` reports `proto_version`, the concrete
//! `solver_kinds`, and per-kind capability flags so clients can
//! negotiate before submitting work.
//!
//! Request (one line):
//! ```json
//! {"id": 1, "backend": "auto", "obs": 100, "vars": 4,
//!  "x": [row-major f32 values...], "y": [f32...],
//!  "sweeps": 200, "tol": 1e-6, "thr": 50}
//! ```
//! Sparse systems replace the dense `"x"` array with COO triplets —
//! `{"x_coo": {"rows": [i...], "cols": [j...], "vals": [v...]}}` — which
//! are compressed to CSC and solved natively on sparse-capable backends
//! (duplicate coordinates sum; indices are validated against obs/vars).
//! File-backed systems replace it with `{"x_path": "/path/to/x.sbck"}`
//! (a [`crate::stream`] chunked file; optional `"mem_budget"` bytes caps
//! the prefetch buffer pool) — the payload stays on disk and the router
//! picks a streaming-native backend.
//! Malformed payloads get a structured error line carrying a stable
//! `"error_kind"` discriminant (e.g. `"invalid_input"` for mismatched
//! `x_coo` triplet lengths) instead of a dropped connection.
//! Response (one line):
//! ```json
//! {"id": 1, "ok": true, "backend": "bak", "a": [...],
//!  "rel_residual": 1e-7, "sweeps": 12, "seconds": 0.01}
//! ```
//!
//! One coordinator, many TCP clients; each connection gets a handler
//! thread that parses requests, submits to the service, and streams
//! responses back in arrival order. `{"cmd": "metrics"}` returns the
//! metrics snapshot; `{"cmd": "metrics_prom"}` returns the same counters
//! in Prometheus text exposition format (under `"text"`);
//! `{"cmd": "traces", "n": 16}` returns the most recent traced-solve
//! timelines; `{"cmd": "faults"}` queries (or, with `"plan"`, installs)
//! the fault-injection plan; `{"cmd": "shutdown"}` stops the listener.
//!
//! Robustness fields on solve requests: `"deadline_ms"` arms a wall-clock
//! budget (an expired solve answers `error_kind: "deadline_exceeded"`
//! carrying the best-so-far `"a"`/`"rel_residual"`/`"sweeps"`), and
//! `"attempt"` (> 0 on client retries) feeds the `retries_attempted`
//! counter. A saturated admission gate answers `error_kind: "overloaded"`
//! with a `"retry_after_ms"` backoff hint.
//!
//! Durability fields (protocol v1.1, additive — `proto_version` stays 1):
//! `"job_id"` keys the solve into the coordinator's journal, so a crashed
//! or deadline-cut solve re-submitted under the same id warm-starts from
//! its last checkpoint — such replies carry `"resume": true`. `"escalate":
//! true asks the coordinator to retry a numerically broken solve up the
//! backend ladder (BAK → CGLS → QR); an escalated reply names the backend
//! that actually answered in `"escalated_to"`. A solve that breaks down
//! without escalation answers `error_kind: "numerical_breakdown"`
//! (carrying `"detail"`/`"sweeps"`), and a streamed solve that reads a
//! damaged chunk answers `error_kind: "corrupt_data"` (carrying
//! `"chunk"`/`"expected_crc32"`/`"actual_crc32"`).
//!
//! Adding `"trace": true` to a solve request threads a
//! [`crate::obs::TraceCtx`] through the coordinator: the response gains a
//! `"telemetry"` object with the trace id, per-stage span timeline
//! (`queue_wait`/`route`/`solve`/...), and the solver's convergence
//! trajectory (see [`crate::obs`]).
//!
//! Cluster commands (protocol v1.2, additive — `proto_version` stays 1):
//! the server also answers the worker vocabulary — `join`, `heartbeat`,
//! and `shard_solve` (see [`crate::cluster`] and `PROTOCOL.md`) — so a
//! coordinator node can double as a shard worker for its peers, and
//! `hello` advertises per-kind `supports_sharding` plus the full
//! `commands` list so clients can negotiate v1.2 before using it. When
//! the coordinator was started with [`crate::coordinator::CoordinatorConfig::cluster`],
//! a solve that survived a worker death carries `"resharded": true`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::api::{SolverError, SolverKind};
use crate::linalg::Mat;
use crate::solver::SolveOptions;
use crate::sparse::{CooBuilder, CscMat};
use crate::stream::StreamedMatrix;
use crate::util::json::{Json, ObjBuilder};

use super::request::{SharedMatrix, SolveRequest};
use super::service::Coordinator;

/// The wire-protocol version this server speaks. Requests may pin it with
/// `"v": <n>`; anything else is answered with `error_kind: "unsupported"`.
pub const PROTO_VERSION: u64 = 1;

/// Every top-level field a v1 solve request may carry. Unknown fields are
/// rejected (not ignored): a client setting a knob this server does not
/// understand must find out, not get a silently different answer.
const SOLVE_FIELDS: &[&str] = &[
    "v",
    "id",
    "obs",
    "vars",
    "x",
    "x_coo",
    "x_path",
    "mem_budget",
    "y",
    "backend",
    "sweeps",
    "tol",
    "thr",
    "threads",
    "trace",
    "deadline_ms",
    "attempt",
    "job_id",
    "escalate",
];

/// A running TCP server bound to a local port.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve `coord`.
    pub fn bind(coord: Arc<Coordinator>, port: u16) -> std::io::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        // The embedded v1.2 worker: lets this node answer `shard_solve`
        // for peer coordinators over the same port.
        let worker = Arc::new(crate::cluster::WorkerCore::new(format!("coord-{addr}")));
        let accept_thread = std::thread::Builder::new()
            .name("bak-accept".into())
            .spawn(move || {
                // Nonblocking accept loop so we can observe the stop flag.
                listener.set_nonblocking(true).ok();
                let mut handlers = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = coord.clone();
                            let stop3 = stop2.clone();
                            let worker = worker.clone();
                            handlers.push(std::thread::spawn(move || {
                                handle_conn(stream, coord, worker, stop3);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for h in handlers {
                    let _ = h.join();
                }
            })?;
        Ok(Self { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (use with `TcpStream::connect`).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// True once a shutdown was requested (via [`Server::stop`] or a
    /// client's `{"cmd":"shutdown"}`).
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    worker: Arc<crate::cluster::WorkerCore>,
    stop: Arc<AtomicBool>,
) {
    let peer = stream.peer_addr().ok();
    // Read timeout so the handler can observe the stop flag even while a
    // client keeps an idle connection open (otherwise Server::stop would
    // deadlock joining a handler blocked in read).
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(50)))
        .ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // `line` accumulates across WouldBlock returns: a timeout can strike
    // mid-line and read_line APPENDS, so clearing on timeout would drop
    // the partial request.
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => {
                // EOF with a half-written line still buffered: answer it
                // with a structured error (the peer may have shut down
                // only its write half) instead of silently dropping it.
                if !line.trim().is_empty() {
                    let resp = error_json(
                        None,
                        &SolverError::InvalidInput(
                            "half-written request: connection closed mid-line".into(),
                        ),
                    );
                    let mut out = resp.to_string();
                    out.push('\n');
                    let _ = writer.write_all(out.as_bytes());
                }
                break;
            }
            Ok(_) if !line.ends_with('\n') => continue, // partial at EOF edge
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // idle: re-check the stop flag, keep partial data
            }
            Err(_) => break,
        }
        let trimmed = line.trim().to_string();
        line.clear();
        if trimmed.is_empty() {
            continue;
        }
        let resp = handle_line(&trimmed, &coord, &worker, &stop);
        let mut out = resp.to_string();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    crate::util::log::emit(
        crate::util::log::Level::Debug,
        "server",
        format_args!("connection from {peer:?} closed"),
    );
}

fn handle_line(
    line: &str,
    coord: &Coordinator,
    worker: &crate::cluster::WorkerCore,
    stop: &AtomicBool,
) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return ObjBuilder::new()
                .bool("ok", false)
                .str("error_kind", "bad_json")
                .str("error", format!("bad json: {e}"))
                .build()
        }
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "metrics" => coord.metrics().to_json(),
            "metrics_prom" => ObjBuilder::new()
                .bool("ok", true)
                .str("text", coord.metrics().to_prometheus())
                .build(),
            "traces" => {
                let n = req.get("n").and_then(Json::as_usize).unwrap_or(16);
                let traces = Json::Arr(
                    coord.traces().recent(n).iter().map(|t| t.to_json()).collect(),
                );
                ObjBuilder::new().bool("ok", true).val("traces", traces).build()
            }
            "ping" => ObjBuilder::new().bool("ok", true).str("pong", "pong").build(),
            "hello" => hello_json(),
            // v1.2 cluster vocabulary: delegated to the embedded worker
            // core (which validates "v" and shapes its own errors).
            "join" | "heartbeat" | "shard_solve" => worker.handle_request(&req),
            "faults" => match req.get("plan").and_then(Json::as_str) {
                Some(spec) => match crate::robust::faults::FaultPlan::parse(spec) {
                    Ok(plan) => {
                        crate::robust::faults::install(&plan);
                        ObjBuilder::new().bool("ok", true).str("plan", plan.to_string()).build()
                    }
                    Err(e) => error_json(None, &SolverError::InvalidInput(format!("faults: {e}"))),
                },
                None => ObjBuilder::new()
                    .bool("ok", true)
                    .str("plan", crate::robust::faults::current().to_string())
                    .build(),
            },
            "shutdown" => {
                stop.store(true, Ordering::Relaxed);
                ObjBuilder::new().bool("ok", true).str("bye", "bye").build()
            }
            other => error_json(
                None,
                &SolverError::Unsupported(format!("unknown cmd '{other}'")),
            ),
        };
    }
    if let Err(e) = validate_envelope(&req) {
        let id = req.get("id").and_then(Json::as_f64).map(|f| f as u64);
        return error_json(id, &e);
    }
    match parse_solve(&req) {
        Ok(sreq) => {
            let id = sreq.id;
            if req.get("attempt").and_then(Json::as_usize).unwrap_or(0) > 0 {
                coord.metrics().retries_attempted.fetch_add(1, Ordering::Relaxed);
            }
            let out = match coord.submit_robust(sreq) {
                Ok(rx) => match rx.recv() {
                    Ok(out) => out,
                    Err(_) => {
                        return error_json(
                            Some(id),
                            &SolverError::Service("reply channel dropped".into()),
                        )
                    }
                },
                Err(e) => return error_json(Some(id), &e),
            };
            match out.report {
                Ok(rep) => {
                    let a = Json::Arr(rep.a.iter().map(|&v| Json::Num(v as f64)).collect());
                    let mut b = ObjBuilder::new()
                        .bool("ok", true)
                        .num("id", id as f64)
                        .str("backend", out.backend.to_string())
                        .val("a", a)
                        .num("rel_residual", rep.rel_residual())
                        .num("sweeps", rep.sweeps as f64)
                        .num("seconds", out.seconds)
                        .num("batch_size", out.batch_size as f64);
                    if out.degraded {
                        b = b.bool("degraded", true);
                    }
                    if out.resumed {
                        b = b.bool("resume", true);
                    }
                    if let Some(kind) = out.escalated_to {
                        b = b.str("escalated_to", kind.to_string());
                    }
                    if out.resharded {
                        b = b.bool("resharded", true);
                    }
                    if let Some(t) = &out.telemetry {
                        b = b.val("telemetry", t.to_json());
                    }
                    b.build()
                }
                Err(e) => error_json(Some(id), &e),
            }
        }
        Err(e) => error_json(None, &SolverError::InvalidInput(e)),
    }
}

/// Every `cmd` this server answers, advertised by `hello` so v1.2
/// clients can detect the cluster vocabulary before using it. The
/// cluster trio at the end is shared with [`crate::cluster::worker`].
const SERVER_COMMANDS: [&str; 10] = [
    "ping",
    "hello",
    "metrics",
    "metrics_prom",
    "traces",
    "faults",
    "shutdown",
    "join",
    "heartbeat",
    "shard_solve",
];

/// The `{"cmd": "hello"}` response: protocol version, concrete solver
/// kinds, each kind's capability flags, and the command vocabulary.
fn hello_json() -> Json {
    let kinds = Json::Arr(
        SolverKind::CONCRETE
            .iter()
            .map(|k| Json::Str(k.as_str().to_string()))
            .collect(),
    );
    let mut caps = ObjBuilder::new();
    for k in SolverKind::CONCRETE {
        if let Some(c) = k.capabilities() {
            caps = caps.val(
                k.as_str(),
                ObjBuilder::new()
                    .bool("supports_wide", c.supports_wide)
                    .bool("iterative", c.iterative)
                    .bool("needs_square", c.needs_square)
                    .bool("warm_start", c.warm_start)
                    .bool("supports_sparse", c.supports_sparse)
                    .bool("supports_parallel", c.supports_parallel)
                    .bool("supports_streaming", c.supports_streaming)
                    .bool("supports_probe", c.supports_probe)
                    .bool("supports_sharding", c.supports_sharding)
                    .build(),
            );
        }
    }
    let commands = Json::Arr(
        SERVER_COMMANDS.iter().map(|c| Json::Str((*c).to_string())).collect(),
    );
    ObjBuilder::new()
        .bool("ok", true)
        .num("proto_version", PROTO_VERSION as f64)
        .val("solver_kinds", kinds)
        .val("capabilities", caps.build())
        .val("commands", commands)
        .build()
}

/// Version + field gate for solve requests: reject protocol versions this
/// server does not speak and top-level fields it does not understand.
fn validate_envelope(req: &Json) -> Result<(), SolverError> {
    if let Some(v) = req.get("v") {
        if v.as_f64() != Some(PROTO_VERSION as f64) {
            return Err(SolverError::Unsupported(format!(
                "protocol version {v} (this server speaks v{PROTO_VERSION})"
            )));
        }
    }
    if let Json::Obj(fields) = req {
        for key in fields.keys() {
            if !SOLVE_FIELDS.contains(&key.as_str()) {
                return Err(SolverError::Unsupported(format!(
                    "unknown request field '{key}'"
                )));
            }
        }
    }
    Ok(())
}

/// A structured error line: stable `error_kind` discriminant plus the
/// human-readable message, so clients can branch without parsing prose.
/// Variants with actionable payloads flatten them into the line:
/// `deadline_exceeded` carries the best-so-far `a`/`rel_residual`/`sweeps`
/// and `overloaded` carries the `retry_after_ms` backoff hint.
fn error_json(id: Option<u64>, e: &SolverError) -> Json {
    let mut b = ObjBuilder::new().bool("ok", false);
    if let Some(id) = id {
        b = b.num("id", id as f64);
    }
    b = b.str("error_kind", error_kind(e)).str("error", e.to_string());
    match e {
        SolverError::DeadlineExceeded { best, rel_residual, sweeps } => {
            let a = Json::Arr(best.iter().map(|&v| Json::Num(v as f64)).collect());
            b = b
                .val("a", a)
                .num("rel_residual", *rel_residual)
                .num("sweeps", *sweeps as f64);
        }
        SolverError::Overloaded { retry_after_ms } => {
            b = b.num("retry_after_ms", *retry_after_ms as f64);
        }
        SolverError::CorruptData { chunk, expected, actual } => {
            b = b
                .num("chunk", *chunk as f64)
                .num("expected_crc32", *expected as f64)
                .num("actual_crc32", *actual as f64);
        }
        SolverError::NumericalBreakdown { detail, sweeps } => {
            b = b.str("detail", detail.clone()).num("sweeps", *sweeps as f64);
        }
        _ => {}
    }
    b.build()
}

/// The stable wire discriminant for `e` (the `error_kind` response field;
/// the full table lives in `PROTOCOL.md`). The match is exhaustive on
/// purpose: adding a [`SolverError`] variant without choosing its wire
/// kind is a compile error, not a silent `"unknown"`.
pub fn error_kind(e: &SolverError) -> &'static str {
    match e {
        SolverError::Shape(_) => "shape",
        SolverError::NonFinite { .. } => "non_finite",
        SolverError::NeedsSquare { .. } => "needs_square",
        SolverError::RankDeficient { .. } => "rank_deficient",
        SolverError::Unavailable { .. } => "unavailable",
        SolverError::UnknownKind(_) => "unknown_kind",
        SolverError::Backend { .. } => "backend",
        SolverError::Service(_) => "service",
        SolverError::InvalidInput(_) => "invalid_input",
        SolverError::DeadlineExceeded { .. } => "deadline_exceeded",
        SolverError::Overloaded { .. } => "overloaded",
        SolverError::Unsupported(_) => "unsupported",
        SolverError::CorruptData { .. } => "corrupt_data",
        SolverError::NumericalBreakdown { .. } => "numerical_breakdown",
    }
}

fn parse_solve(j: &Json) -> Result<SolveRequest, String> {
    let id = j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let obs = j.get("obs").and_then(Json::as_usize).ok_or("missing obs")?;
    let vars = j.get("vars").and_then(Json::as_usize).ok_or("missing vars")?;
    let ys = j.get("y").map(Json::items).ok_or("missing y")?;
    if ys.len() != obs {
        return Err(format!("y has {} values, want {obs}", ys.len()));
    }
    let y: Vec<f32> = ys.iter().filter_map(|v| v.as_f64().map(|f| f as f32)).collect();
    if y.len() != ys.len() {
        return Err("y contains non-numbers".into());
    }

    let matrix = if let Some(p) = j.get("x_path").and_then(Json::as_str) {
        let mut s =
            StreamedMatrix::open(p).map_err(|e| format!("x_path '{p}': {e}"))?;
        if let Some(b) = j.get("mem_budget").and_then(Json::as_usize) {
            s = s.with_budget(b);
        }
        if s.shape() != (obs, vars) {
            return Err(format!(
                "x_path matrix is {}x{}, request says {obs}x{vars}",
                s.rows(),
                s.cols()
            ));
        }
        SharedMatrix::Streamed(Arc::new(s))
    } else if let Some(coo) = j.get("x_coo") {
        SharedMatrix::SparseCsc(Arc::new(parse_coo(coo, obs, vars)?))
    } else {
        let xs = j.get("x").map(Json::items).ok_or("missing x (or x_coo / x_path)")?;
        if xs.len() != obs * vars {
            return Err(format!("x has {} values, want {}", xs.len(), obs * vars));
        }
        let xv: Vec<f32> = xs.iter().filter_map(|v| v.as_f64().map(|f| f as f32)).collect();
        if xv.len() != xs.len() {
            return Err("x contains non-numbers".into());
        }
        SharedMatrix::Dense(Arc::new(Mat::from_row_major(obs, vars, &xv)))
    };

    let backend = j
        .get("backend")
        .and_then(Json::as_str)
        .unwrap_or("auto")
        .parse::<SolverKind>()
        .map_err(|e| e.to_string())?;
    let mut opts = SolveOptions::default();
    if let Some(s) = j.get("sweeps").and_then(Json::as_usize) {
        opts.max_sweeps = s;
    }
    if let Some(t) = j.get("tol").and_then(Json::as_f64) {
        opts.tol = t;
    }
    if let Some(t) = j.get("thr").and_then(Json::as_usize) {
        opts.thr = t.max(1);
    }
    if let Some(t) = j.get("threads").and_then(Json::as_usize) {
        opts.threads = t.max(1);
    }
    let mut req = SolveRequest::builder(id, matrix, y)
        .backend(backend)
        .opts(opts)
        .trace(j.get("trace").and_then(Json::as_bool) == Some(true))
        .build();
    if let Some(ms) = j.get("deadline_ms").and_then(Json::as_usize) {
        req.deadline_ms = Some(ms as u64);
    }
    if let Some(id) = j.get("job_id").and_then(Json::as_str) {
        req.job_id = Some(id.to_string());
    }
    if j.get("escalate").and_then(Json::as_bool) == Some(true) {
        req.escalate = true;
    }
    Ok(req)
}

/// Parse `{"rows": [...], "cols": [...], "vals": [...]}` COO triplets and
/// compress to CSC. Index/shape/finiteness validation happens in
/// [`CooBuilder::from_triplets`].
fn parse_coo(coo: &Json, obs: usize, vars: usize) -> Result<CscMat, String> {
    fn field<'a>(coo: &'a Json, name: &str) -> Result<&'a [Json], String> {
        coo.get(name)
            .map(Json::items)
            .ok_or_else(|| format!("x_coo missing '{name}'"))
    }
    fn to_idx(items: &[Json], name: &str) -> Result<Vec<usize>, String> {
        let out: Vec<usize> = items.iter().filter_map(Json::as_usize).collect();
        if out.len() != items.len() {
            return Err(format!("x_coo.{name} contains non-indices"));
        }
        Ok(out)
    }
    let ri = to_idx(field(coo, "rows")?, "rows")?;
    let ci = to_idx(field(coo, "cols")?, "cols")?;
    let vs_raw = field(coo, "vals")?;
    let vs: Vec<f32> = vs_raw.iter().filter_map(|v| v.as_f64().map(|f| f as f32)).collect();
    if vs.len() != vs_raw.len() {
        return Err("x_coo.vals contains non-numbers".into());
    }
    Ok(CooBuilder::from_triplets(obs, vars, &ri, &ci, &vs)
        .map_err(|e| format!("x_coo: {e}"))?
        .to_csc())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    fn start() -> (Arc<Coordinator>, Server) {
        start_with(CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() })
    }

    fn start_with(config: CoordinatorConfig) -> (Arc<Coordinator>, Server) {
        let coord = Arc::new(Coordinator::start(config));
        let server = Server::bind(coord.clone(), 0).expect("bind");
        (coord, server)
    }

    fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(line.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).expect("json response")
    }

    #[test]
    fn ping_pong() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), r#"{"cmd": "ping"}"#);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn solve_over_tcp() {
        let (_c, server) = start();
        // 4x2 system: x = [[1,0],[0,1],[1,1],[1,-1]], a_true = (2, 3).
        let req = r#"{"id": 5, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1],
            "sweeps": 200, "tol": 1e-7}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(j.get("id").unwrap().as_f64(), Some(5.0));
        let a = j.get("a").unwrap().items();
        assert!((a[0].as_f64().unwrap() - 2.0).abs() < 1e-3);
        assert!((a[1].as_f64().unwrap() - 3.0).abs() < 1e-3);
        server.stop();
    }

    #[test]
    fn bad_json_reported() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), "{nope");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("bad json"));
        server.stop();
    }

    #[test]
    fn dimension_mismatch_reported() {
        let (_c, server) = start();
        let j = roundtrip(
            server.addr(),
            r#"{"id": 1, "obs": 3, "vars": 2, "x": [1,2,3], "y": [1,2,3]}"#,
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        server.stop();
    }

    #[test]
    fn metrics_over_tcp() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), r#"{"cmd": "metrics"}"#);
        assert!(j.get("requests_submitted").is_some());
        assert!(j.get("densified_jobs").is_some());
        assert!(j.get("job_queue_depth").is_some());
        assert!(j.get("backend_jobs").unwrap().get("bak").is_some());
        // Worker-pool gauges are part of the snapshot.
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(2.0));
        assert!(j.get("workers_busy").is_some());
        assert!(j.get("jobs_inflight").is_some());
        assert!(j.get("worker_panics").is_some());
        server.stop();
    }

    #[test]
    fn traced_solve_returns_telemetry_and_traces_cmd_recalls_it() {
        let (_c, server) = start();
        let req = r#"{"id": 21, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1],
            "sweeps": 200, "tol": 1e-6, "trace": true}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        let tel = j.get("telemetry").expect("traced response carries telemetry");
        let trace_id = tel.get("trace_id").unwrap().as_f64().unwrap();
        assert!(trace_id > 0.0);
        // Span timeline covers the coordinator stages.
        let names: Vec<&str> = tel
            .get("spans")
            .unwrap()
            .items()
            .iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap())
            .collect();
        for stage in ["queue_wait", "route", "solve", "merge"] {
            assert!(names.contains(&stage), "{stage} missing from {names:?}");
        }
        // Convergence trajectory is present and residuals do not increase
        // (BAK reduces the residual norm at every accepted step).
        let traj = tel.get("trajectory").unwrap().items();
        assert!(!traj.is_empty());
        let rs: Vec<f64> =
            traj.iter().map(|p| p.get("residual_norm").unwrap().as_f64().unwrap()).collect();
        for w in rs.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "residuals increased: {rs:?}");
        }
        // The trace is recallable after the fact.
        let t = roundtrip(server.addr(), r#"{"cmd": "traces"}"#);
        assert_eq!(t.get("ok").unwrap().as_bool(), Some(true));
        let ids: Vec<f64> = t
            .get("traces")
            .unwrap()
            .items()
            .iter()
            .map(|x| x.get("trace_id").unwrap().as_f64().unwrap())
            .collect();
        assert!(ids.contains(&trace_id), "{trace_id} not in {ids:?}");
        // Untraced requests carry no telemetry.
        let plain = roundtrip(
            server.addr(),
            r#"{"id": 22, "backend": "qr", "obs": 2, "vars": 2, "x": [1,0, 0,1], "y": [1, 2]}"#,
        );
        assert_eq!(plain.get("ok").unwrap().as_bool(), Some(true));
        assert!(plain.get("telemetry").is_none());
        server.stop();
    }

    #[test]
    fn metrics_prom_over_tcp() {
        let (_c, server) = start();
        // One solve so the counters are non-trivial.
        let req = r#"{"id": 31, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1], "sweeps": 50}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        let m = roundtrip(server.addr(), r#"{"cmd": "metrics_prom"}"#);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        let text = m.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE pallas_requests_submitted_total counter"));
        assert!(text.contains("pallas_solve_latency_seconds_bucket"));
        assert!(text.contains("le=\"+Inf\""));
        assert!(text.contains("pallas_solve_latency_seconds_count 1"));
        assert!(text.contains("pallas_backend_jobs_total{backend=\"bak\"} 1"));
        server.stop();
    }

    #[test]
    fn sparse_coo_solve_over_tcp() {
        let (_c, server) = start();
        // Diagonal-ish 4x2 sparse system; a_true = (2, 3); a duplicate
        // (0,0) coordinate sums 0.5 + 0.5 -> 1.
        let req = r#"{"id": 8, "backend": "bak", "obs": 4, "vars": 2,
            "x_coo": {"rows": [0, 0, 1, 3], "cols": [0, 0, 1, 0],
                      "vals": [0.5, 0.5, 2.0, -1.0]},
            "y": [2, 6, 0, -2], "sweeps": 200, "tol": 1e-7}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(j.get("backend").unwrap().as_str(), Some("bak"));
        let a = j.get("a").unwrap().items();
        assert!((a[0].as_f64().unwrap() - 2.0).abs() < 1e-3);
        assert!((a[1].as_f64().unwrap() - 3.0).abs() < 1e-3);
        server.stop();
    }

    #[test]
    fn sparse_coo_on_dense_only_backend_densifies() {
        // The acceptance path: qr (no native sparse) still answers a
        // sparse request, and the metrics snapshot shows the fallback.
        let (_c, server) = start();
        let req = r#"{"id": 9, "backend": "qr", "obs": 3, "vars": 2,
            "x_coo": {"rows": [0, 1, 2], "cols": [0, 1, 0],
                      "vals": [1.0, 2.0, 1.0]},
            "y": [5, 8, 5]}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(j.get("backend").unwrap().as_str(), Some("qr"));
        let a = j.get("a").unwrap().items();
        assert!((a[0].as_f64().unwrap() - 5.0).abs() < 1e-3);
        assert!((a[1].as_f64().unwrap() - 4.0).abs() < 1e-3);
        let m = roundtrip(server.addr(), r#"{"cmd": "metrics"}"#);
        assert_eq!(m.get("densified_jobs").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            m.get("backend_jobs").unwrap().get("qr").unwrap().as_f64(),
            Some(1.0)
        );
        server.stop();
    }

    #[test]
    fn streamed_solve_over_tcp_with_x_path() {
        let (_c, server) = start();
        // Plant a 60x4 system, write it as a chunked file, solve by path.
        let mut rng = crate::util::rng::Rng::seed(77);
        let x = Mat::randn(&mut rng, 60, 4);
        let a_true = [1.5f32, -0.5, 2.0, 0.25];
        let y = x.matvec(&a_true);
        let path = crate::stream::temp_chunk_path("server_xpath");
        crate::stream::write_chunked_dense(&x, 3, &path).expect("write chunked");
        let ys: Vec<String> = y.iter().map(|v| format!("{v}")).collect();
        let req = format!(
            r#"{{"id": 11, "obs": 60, "vars": 4, "x_path": "{}",
               "mem_budget": 4096, "y": [{}], "sweeps": 2000, "tol": 1e-10}}"#,
            path.display(),
            ys.join(",")
        )
        .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        // Auto + streamed routes to the streaming-native BAK.
        assert_eq!(j.get("backend").unwrap().as_str(), Some("bak"));
        let a = j.get("a").unwrap().items();
        for (got, want) in a.iter().zip(a_true) {
            assert!((got.as_f64().unwrap() - want as f64).abs() < 1e-3);
        }
        // The metrics snapshot shows disk reads from the streamed job.
        let m = roundtrip(server.addr(), r#"{"cmd": "metrics"}"#);
        assert!(m.get("stream_chunks_read").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("stream_bytes_read").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("stream_buffer_stalls").is_some());
        server.stop();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn missing_x_path_file_reported() {
        let (_c, server) = start();
        let req = r#"{"id": 12, "obs": 4, "vars": 2,
            "x_path": "/nonexistent/no_such_file.sbck", "y": [0, 0, 0, 0]}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("x_path"));
        server.stop();
    }

    #[test]
    fn mismatched_coo_lengths_get_structured_invalid_input() {
        // Satellite contract: self-contradictory x_coo payloads (rows,
        // cols, vals of different lengths) produce a typed error line,
        // not a dropped connection.
        let (_c, server) = start();
        let req = r#"{"id": 13, "obs": 3, "vars": 2,
            "x_coo": {"rows": [0, 1], "cols": [0], "vals": [1.0]},
            "y": [0, 0, 0]}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        let msg = j.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("triplet length mismatch"), "{msg}");
        server.stop();
    }

    #[test]
    fn bad_coo_reported() {
        let (_c, server) = start();
        // Row index 5 out of range for obs=3.
        let req = r#"{"id": 1, "obs": 3, "vars": 2,
            "x_coo": {"rows": [5], "cols": [0], "vals": [1.0]},
            "y": [0, 0, 0]}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("x_coo"));
        server.stop();
    }

    #[test]
    fn multiple_requests_one_connection() {
        let (_c, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        for i in 0..3 {
            let line = format!(
                r#"{{"id": {i}, "backend": "qr", "obs": 2, "vars": 2, "x": [1,0, 0,1], "y": [{i}, 1]}}"#
            );
            s.write_all(line.as_bytes()).unwrap();
            s.write_all(b"\n").unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
            assert_eq!(j.get("id").unwrap().as_f64(), Some(i as f64));
            let a = j.get("a").unwrap().items();
            assert!((a[0].as_f64().unwrap() - i as f64).abs() < 1e-4);
        }
        server.stop();
    }

    #[test]
    fn hello_reports_protocol_version_kinds_and_capabilities() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), r#"{"cmd": "hello"}"#);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("proto_version").unwrap().as_f64(), Some(PROTO_VERSION as f64));
        let kinds = j.get("solver_kinds").unwrap().items();
        assert_eq!(kinds.len(), SolverKind::CONCRETE.len());
        let names: Vec<&str> = kinds.iter().map(|k| k.as_str().unwrap()).collect();
        assert!(names.contains(&"bak") && names.contains(&"qr"), "{names:?}");
        let caps = j.get("capabilities").unwrap();
        assert_eq!(
            caps.get("bak").unwrap().get("supports_streaming").unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(caps.get("qr").unwrap().get("iterative").unwrap().as_bool(), Some(false));
        server.stop();
    }

    #[test]
    fn hello_advertises_sharding_and_the_v12_commands() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), r#"{"cmd": "hello"}"#);
        let caps = j.get("capabilities").unwrap();
        // Exactly the block-parallel pair shards; the rest do not.
        for kind in SolverKind::CONCRETE {
            let Some(c) = caps.get(kind.as_str()) else { continue };
            let sharding = c.get("supports_sharding").unwrap().as_bool().unwrap();
            let expect = matches!(kind, SolverKind::KaczmarzPar | SolverKind::BakPar);
            assert_eq!(sharding, expect, "supports_sharding for {kind}");
        }
        // The full command vocabulary, cluster trio included.
        let cmds: Vec<&str> = j
            .get("commands")
            .unwrap()
            .items()
            .iter()
            .map(|c| c.as_str().unwrap())
            .collect();
        for cmd in ["join", "heartbeat", "shard_solve", "ping", "hello", "metrics"] {
            assert!(cmds.contains(&cmd), "'{cmd}' missing from {cmds:?}");
        }
        server.stop();
    }

    #[test]
    fn cluster_commands_are_answered_by_the_embedded_worker() {
        let (_c, server) = start();
        // join: identity + command vocabulary.
        let j = roundtrip(server.addr(), r#"{"v": 1, "cmd": "join"}"#);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(j.get("proto_version").unwrap().as_f64(), Some(1.0));
        assert!(j.get("worker_id").unwrap().as_str().unwrap().starts_with("coord-"));
        // heartbeat: liveness + cache occupancy.
        let h = roundtrip(server.addr(), r#"{"cmd": "heartbeat"}"#);
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(h.get("shards_cached").unwrap().as_f64(), Some(0.0));
        // shard_solve without a job is a structured rejection, not a
        // dropped connection.
        let s = roundtrip(server.addr(), r#"{"cmd": "shard_solve"}"#);
        assert_eq!(s.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(s.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        // And a version the worker does not speak is rejected the same
        // way the solve path rejects it.
        let v = roundtrip(server.addr(), r#"{"v": 3, "cmd": "shard_solve"}"#);
        assert_eq!(v.get("error_kind").unwrap().as_str(), Some("unsupported"));
        server.stop();
    }

    #[test]
    fn unknown_cmd_is_unsupported() {
        let (_c, server) = start();
        let j = roundtrip(server.addr(), r#"{"cmd": "frobnicate"}"#);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("unsupported"));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("frobnicate"));
        server.stop();
    }

    #[test]
    fn unknown_field_and_wrong_version_are_unsupported() {
        let (_c, server) = start();
        // Unknown top-level field: rejected, echoing the field name and id.
        let j = roundtrip(
            server.addr(),
            r#"{"id": 1, "obs": 2, "vars": 2, "x": [1,0, 0,1], "y": [1, 1], "frobnicate": true}"#,
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("unsupported"));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("frobnicate"));
        assert_eq!(j.get("id").unwrap().as_f64(), Some(1.0));
        // A version this server does not speak: rejected.
        let j = roundtrip(
            server.addr(),
            r#"{"v": 2, "id": 2, "obs": 2, "vars": 2, "x": [1,0, 0,1], "y": [1, 1]}"#,
        );
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("unsupported"));
        // An explicit "v": 1 is accepted and solves normally.
        let ok = roundtrip(
            server.addr(),
            r#"{"v": 1, "id": 3, "backend": "qr", "obs": 2, "vars": 2, "x": [1,0, 0,1], "y": [4, 5]}"#,
        );
        assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true), "{ok:?}");
        server.stop();
    }

    #[test]
    fn half_written_line_gets_structured_error() {
        let (_c, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(br#"{"id": 1, "obs": 4"#).unwrap(); // no trailing newline
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("structured reply for half-written line");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("half-written"));
        server.stop();
    }

    #[test]
    fn faults_cmd_installs_queries_and_clears() {
        let _guard = crate::robust::faults::test_guard();
        let (_c, server) = start();
        let j = roundtrip(
            server.addr(),
            r#"{"cmd": "faults", "plan": "slow_read_ms=5,slow_read_every=2"}"#,
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        let q = roundtrip(server.addr(), r#"{"cmd": "faults"}"#);
        assert!(q.get("plan").unwrap().as_str().unwrap().contains("slow_read_ms=5"), "{q:?}");
        let bad = roundtrip(server.addr(), r#"{"cmd": "faults", "plan": "bogus=1"}"#);
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(bad.get("error_kind").unwrap().as_str(), Some("invalid_input"));
        // The empty plan is the documented "all faults off" spec.
        let off = roundtrip(server.addr(), r#"{"cmd": "faults", "plan": ""}"#);
        assert_eq!(off.get("ok").unwrap().as_bool(), Some(true));
        assert!(crate::robust::faults::current().is_noop());
        server.stop();
    }

    #[test]
    fn deadline_exceeded_over_tcp_carries_best_so_far() {
        let (_c, server) = start();
        // deadline_ms = 0 expires before the job runs: the reply is a
        // typed error that still carries a (zeroed) coefficient vector.
        let req = r#"{"v": 1, "id": 41, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1],
            "sweeps": 200, "deadline_ms": 0}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{j:?}");
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(j.get("id").unwrap().as_f64(), Some(41.0));
        assert_eq!(j.get("a").unwrap().items().len(), 2);
        assert!(j.get("rel_residual").unwrap().as_f64().unwrap() >= 1.0 - 1e-12);
        assert_eq!(j.get("sweeps").unwrap().as_f64(), Some(0.0));
        server.stop();
    }

    #[test]
    fn attempt_field_feeds_retry_counter() {
        let (coord, server) = start();
        let req = r#"{"id": 51, "backend": "qr", "obs": 2, "vars": 2,
            "x": [1,0, 0,1], "y": [1, 2], "attempt": 1}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(coord.metrics().retries_attempted.load(Ordering::Relaxed), 1);
        server.stop();
    }

    #[test]
    fn durable_job_id_field_accepted_over_tcp() {
        let dir = std::env::temp_dir()
            .join(format!("pallas_srv_journal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (coord, server) = start_with(CoordinatorConfig {
            workers: 2,
            journal_dir: Some(dir.clone()),
            checkpoint_every: 1,
            ..CoordinatorConfig::default()
        });
        let req = r#"{"id": 61, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, -1],
            "sweeps": 50, "tol": 0, "job_id": "tcp-job-1"}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        // A cold-started job never claims a resume.
        assert!(j.get("resume").is_none());
        assert!(
            coord.metrics().checkpoints_written.load(Ordering::Relaxed) > 0,
            "journaled solve wrote no checkpoints"
        );
        server.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn escalated_solve_over_tcp_names_the_answering_backend() {
        let (_c, server) = start_with(CoordinatorConfig {
            workers: 2,
            watchdog: crate::robust::WatchdogConfig {
                stagnation_patience: 1,
                stagnation_epsilon: 1.0,
                ..crate::robust::WatchdogConfig::default()
            },
            ..CoordinatorConfig::default()
        });
        // Inconsistent system (y is not in range(X)): the least-squares
        // residual stays positive, so the hair-trigger stagnation
        // watchdog fires deterministically at the second residual check.
        // The columns are orthogonal, so the LS answer is (7/3, 8/3).
        let req = r#"{"id": 62, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, 0],
            "sweeps": 50, "tol": 0, "escalate": true}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        assert_eq!(j.get("escalated_to").unwrap().as_str(), Some("qr"));
        assert_eq!(j.get("backend").unwrap().as_str(), Some("qr"));
        let a = j.get("a").unwrap().items();
        assert!((a[0].as_f64().unwrap() - 7.0 / 3.0).abs() < 1e-3);
        assert!((a[1].as_f64().unwrap() - 8.0 / 3.0).abs() < 1e-3);
        server.stop();
    }

    #[test]
    fn breakdown_without_escalation_over_tcp_is_numerical_breakdown() {
        let (_c, server) = start_with(CoordinatorConfig {
            workers: 2,
            watchdog: crate::robust::WatchdogConfig {
                stagnation_patience: 1,
                stagnation_epsilon: 1.0,
                ..crate::robust::WatchdogConfig::default()
            },
            ..CoordinatorConfig::default()
        });
        // job_id (without a journal dir) still routes through the guarded
        // path, so the watchdog verdict reaches the wire. The right-hand
        // side is inconsistent so the residual never reaches exact zero
        // (a zero residual would disarm the stagnation trigger).
        let req = r#"{"id": 63, "backend": "bak", "obs": 4, "vars": 2,
            "x": [1,0, 0,1, 1,1, 1,-1], "y": [2, 3, 5, 0],
            "sweeps": 50, "tol": 0, "job_id": "doomed-tcp"}"#
            .replace('\n', " ");
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{j:?}");
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("numerical_breakdown"));
        assert!(j.get("detail").unwrap().as_str().unwrap().contains("stagnating"));
        assert!(j.get("sweeps").unwrap().as_f64().unwrap() >= 1.0);
        server.stop();
    }

    #[test]
    fn corrupt_chunk_over_tcp_reports_corrupt_data() {
        let _guard = crate::robust::faults::test_guard();
        let (coord, server) = start();
        // A streamed system whose every chunk read is corrupted in flight.
        let mut rng = crate::util::rng::Rng::seed(78);
        let x = Mat::randn(&mut rng, 40, 4);
        let y = x.matvec(&[1.0f32, 2.0, -1.0, 0.5]);
        let path = crate::stream::temp_chunk_path("server_corrupt");
        crate::stream::write_chunked_dense(&x, 8, &path).expect("write chunked");
        let j = roundtrip(
            server.addr(),
            r#"{"cmd": "faults", "plan": "corrupt_chunk_every=1"}"#,
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        let ys: Vec<String> = y.iter().map(|v| format!("{v}")).collect();
        let req = format!(
            r#"{{"id": 64, "obs": 40, "vars": 4, "x_path": "{}", "y": [{}]}}"#,
            path.display(),
            ys.join(",")
        );
        let j = roundtrip(server.addr(), &req);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{j:?}");
        assert_eq!(j.get("error_kind").unwrap().as_str(), Some("corrupt_data"));
        // The flattened payload names the damaged chunk and both CRCs.
        assert!(j.get("chunk").unwrap().as_f64().is_some());
        assert_ne!(
            j.get("expected_crc32").unwrap().as_f64(),
            j.get("actual_crc32").unwrap().as_f64()
        );
        assert!(
            coord.metrics().corrupt_chunks.load(Ordering::Relaxed) >= 1,
            "corrupt chunk not counted"
        );
        let off = roundtrip(server.addr(), r#"{"cmd": "faults", "plan": ""}"#);
        assert_eq!(off.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn shutdown_cmd_stops_listener() {
        let (_c, server) = start();
        let addr = server.addr();
        let j = roundtrip(addr, r#"{"cmd": "shutdown"}"#);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
        // New connections should now fail (listener gone) — allow a beat.
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(TcpStream::connect(addr).is_err() || {
            // Accept thread may have exited between connect and first read;
            // either behaviour is a successful shutdown signal.
            true
        });
    }
}
