//! Request/response types for the solve service.

use std::sync::Arc;

use crate::api::{MatrixRef, SolverError, SolverKind};
use crate::linalg::Mat;
use crate::solver::{SolveOptions, SolveReport};
use crate::sparse::CscMat;
use crate::stream::StreamedMatrix;

/// Backwards-compatible alias: the coordinator used to define its own
/// `Backend` enum; requests are now addressed by the crate-wide
/// [`SolverKind`] (any registered solver, not just the original four).
pub use crate::api::SolverKind as Backend;

/// A shareable system matrix: dense, compressed sparse column, or a
/// file-backed streamed handle, behind an `Arc` so the batcher can
/// coalesce requests over the same data without copies. The owned
/// counterpart of [`MatrixRef`].
#[derive(Clone)]
pub enum SharedMatrix {
    Dense(Arc<Mat>),
    SparseCsc(Arc<CscMat>),
    /// On-disk chunked matrix ([`crate::stream`]); the handle is tiny —
    /// only chunk buffers are ever resident.
    Streamed(Arc<StreamedMatrix>),
}

impl SharedMatrix {
    pub fn rows(&self) -> usize {
        match self {
            SharedMatrix::Dense(m) => m.rows(),
            SharedMatrix::SparseCsc(s) => s.rows(),
            SharedMatrix::Streamed(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SharedMatrix::Dense(m) => m.cols(),
            SharedMatrix::SparseCsc(s) => s.cols(),
            SharedMatrix::Streamed(s) => s.cols(),
        }
    }

    /// (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, SharedMatrix::SparseCsc(_))
    }

    /// True when the matrix payload lives on disk.
    pub fn is_streamed(&self) -> bool {
        matches!(self, SharedMatrix::Streamed(_))
    }

    /// Borrowed view for the [`crate::api::Problem`] layer.
    pub fn matrix_ref(&self) -> MatrixRef<'_> {
        match self {
            SharedMatrix::Dense(m) => MatrixRef::Dense(m),
            SharedMatrix::SparseCsc(s) => MatrixRef::SparseCsc(s),
            SharedMatrix::Streamed(s) => MatrixRef::Streamed(s),
        }
    }

    /// A stable identity (pointer identity of the Arc allocation) — the
    /// batching key. Allocations of different kinds can never collide.
    pub fn key(&self) -> usize {
        match self {
            SharedMatrix::Dense(m) => Arc::as_ptr(m) as usize,
            SharedMatrix::SparseCsc(s) => Arc::as_ptr(s) as usize,
            SharedMatrix::Streamed(s) => Arc::as_ptr(s) as usize,
        }
    }
}

impl From<Arc<Mat>> for SharedMatrix {
    fn from(m: Arc<Mat>) -> Self {
        SharedMatrix::Dense(m)
    }
}

impl From<Arc<CscMat>> for SharedMatrix {
    fn from(s: Arc<CscMat>) -> Self {
        SharedMatrix::SparseCsc(s)
    }
}

impl From<Arc<StreamedMatrix>> for SharedMatrix {
    fn from(s: Arc<StreamedMatrix>) -> Self {
        SharedMatrix::Streamed(s)
    }
}

/// A solve request: one matrix, one or more right-hand sides.
#[derive(Clone)]
pub struct SolveRequest {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    pub x: SharedMatrix,
    pub y: Vec<f32>,
    pub opts: SolveOptions,
    pub backend: SolverKind,
    /// Optional trace context ([`crate::obs::TraceCtx`]): when set, the
    /// coordinator records a per-stage span timeline and a convergence
    /// trajectory for this request, returns them in the outcome's
    /// `telemetry`, and never coalesces the request with others (the
    /// timeline must describe exactly one solve).
    pub trace: Option<Arc<crate::obs::TraceCtx>>,
    /// Optional wall-clock budget for the whole job (queue wait included).
    /// The coordinator arms a [`crate::robust::CancelToken`] at submit
    /// time; an expired solve returns
    /// [`SolverError::DeadlineExceeded`] carrying the best-so-far
    /// solution. Deadline-armed requests are never coalesced.
    pub deadline_ms: Option<u64>,
    /// Set by the coordinator when admission control downgraded this
    /// request to a reduced-sweep solve instead of shedding it.
    pub degraded: bool,
    /// Client-supplied idempotency key. When set (and the coordinator has
    /// a journal directory), the solve checkpoints its resumable state to
    /// `<journal>/<job_id>.ckpt` every N sweeps, and a re-submission under
    /// the same key warm-starts from the last checkpoint instead of
    /// solving from scratch. Durable requests are never coalesced.
    pub job_id: Option<String>,
    /// On numerical breakdown (NaN/Inf residual, sustained divergence),
    /// retry on the next backend up the robustness ladder
    /// (BAK → CGLS → QR) instead of failing with
    /// [`SolverError::NumericalBreakdown`]. Escalating requests are never
    /// coalesced.
    pub escalate: bool,
}

impl SolveRequest {
    /// Construct a dense request with defaults.
    pub fn new(id: u64, x: Arc<Mat>, y: Vec<f32>) -> Self {
        Self::with_matrix(id, SharedMatrix::Dense(x), y)
    }

    /// Construct a sparse request with defaults.
    #[deprecated(since = "0.8.0", note = "use SolveRequest::builder(id, csc, y).build()")]
    pub fn new_sparse(id: u64, x: Arc<CscMat>, y: Vec<f32>) -> Self {
        Self::with_matrix(id, SharedMatrix::SparseCsc(x), y)
    }

    /// Construct a file-backed (streamed) request with defaults.
    #[deprecated(since = "0.8.0", note = "use SolveRequest::builder(id, streamed, y).build()")]
    pub fn new_streamed(id: u64, x: Arc<StreamedMatrix>, y: Vec<f32>) -> Self {
        Self::with_matrix(id, SharedMatrix::Streamed(x), y)
    }

    /// Construct from an already-wrapped [`SharedMatrix`].
    pub fn with_matrix(id: u64, x: SharedMatrix, y: Vec<f32>) -> Self {
        Self {
            id,
            x,
            y,
            opts: SolveOptions::default(),
            backend: SolverKind::Auto,
            trace: None,
            deadline_ms: None,
            degraded: false,
            job_id: None,
            escalate: false,
        }
    }

    /// Start building a request. `x` accepts any of `Arc<Mat>`,
    /// `Arc<CscMat>`, `Arc<StreamedMatrix>` or a [`SharedMatrix`]:
    ///
    /// ```ignore
    /// let req = SolveRequest::builder(1, x, y)
    ///     .backend(SolverKind::Bak)
    ///     .deadline_ms(250)
    ///     .build();
    /// ```
    pub fn builder(id: u64, x: impl Into<SharedMatrix>, y: Vec<f32>) -> SolveRequestBuilder {
        SolveRequestBuilder {
            req: Self::with_matrix(id, x.into(), y),
        }
    }

    /// Attach a fresh trace context (see the `trace` field).
    #[deprecated(since = "0.8.0", note = "use SolveRequest::builder(..).trace(true)")]
    pub fn traced(mut self) -> Self {
        self.trace = Some(crate::obs::TraceCtx::fresh());
        self
    }

    /// A stable identity for the shared matrix — the batching key.
    pub fn matrix_key(&self) -> usize {
        self.x.key()
    }
}

/// Fluent construction for [`SolveRequest`], mirroring
/// [`SolveOptions::builder`]. Unset knobs keep the request defaults.
pub struct SolveRequestBuilder {
    req: SolveRequest,
}

impl SolveRequestBuilder {
    /// Replace the solver options wholesale.
    pub fn opts(mut self, opts: SolveOptions) -> Self {
        self.req.opts = opts;
        self
    }

    /// Pin a solver backend (default: [`SolverKind::Auto`]).
    pub fn backend(mut self, backend: SolverKind) -> Self {
        self.req.backend = backend;
        self
    }

    /// Arm a wall-clock deadline in milliseconds.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.req.deadline_ms = Some(ms);
        self
    }

    /// Record a span timeline + convergence trajectory for this request.
    pub fn trace(mut self, on: bool) -> Self {
        self.req.trace = if on {
            Some(crate::obs::TraceCtx::fresh())
        } else {
            None
        };
        self
    }

    /// Attach an idempotency key: the solve journals resumable
    /// checkpoints under it, and a crash-recovery re-submission with the
    /// same key warm-starts from the last one (see
    /// [`SolveRequest::job_id`]).
    pub fn job_id(mut self, id: impl Into<String>) -> Self {
        self.req.job_id = Some(id.into());
        self
    }

    /// Escalate numerical breakdowns up the backend ladder instead of
    /// failing (see [`SolveRequest::escalate`]).
    pub fn escalate(mut self, on: bool) -> Self {
        self.req.escalate = on;
        self
    }

    pub fn build(self) -> SolveRequest {
        self.req
    }
}

/// A batched job: one matrix, many RHS (one per original request).
pub struct SolveJob {
    pub x: SharedMatrix,
    /// (request id, rhs) pairs.
    pub members: Vec<(u64, Vec<f32>)>,
    pub opts: SolveOptions,
    pub backend: SolverKind,
    /// Trace context carried over from a traced request (always a
    /// singleton job — the scheduler never coalesces traced requests).
    pub trace: Option<Arc<crate::obs::TraceCtx>>,
    /// True when admission control downgraded this job to a
    /// reduced-sweep solve (propagated to every member outcome).
    pub degraded: bool,
    /// Idempotency key carried over from a durable request (always a
    /// singleton job — durable requests are never coalesced, so the
    /// journal checkpoint describes exactly one solve).
    pub job_id: Option<String>,
    /// Breakdown-escalation flag carried over from the request (also a
    /// singleton: a ladder retry must not re-run batch-mates).
    pub escalate: bool,
}

impl SolveJob {
    /// Wrap a single request.
    pub fn single(req: SolveRequest) -> Self {
        Self {
            x: req.x,
            members: vec![(req.id, req.y)],
            opts: req.opts,
            backend: req.backend,
            trace: req.trace,
            degraded: req.degraded,
            job_id: req.job_id,
            escalate: req.escalate,
        }
    }

    /// Number of member requests.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Response for one member request.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub id: u64,
    pub report: Result<SolveReport, SolverError>,
    /// Which backend actually ran.
    pub backend: SolverKind,
    /// Wall time for the member's solve (seconds). Batched members share
    /// the matrix walk; this is the per-member attributed time.
    pub seconds: f64,
    /// How many requests were coalesced into the job this ran in.
    pub batch_size: usize,
    /// Span timeline + convergence trajectory, present only for traced
    /// requests (`SolveRequest::builder(..).trace(true)`).
    pub telemetry: Option<crate::obs::Telemetry>,
    /// True when admission control answered this request with a
    /// reduced-sweep (degraded-mode) solve.
    pub degraded: bool,
    /// True when a durable (`job_id`-keyed) request warm-started from a
    /// journal checkpoint instead of solving from scratch.
    pub resumed: bool,
    /// The ladder rung that finally answered, when a numerical breakdown
    /// was escalated (`SolveRequest::escalate`); `backend` is set to the
    /// same kind.
    pub escalated_to: Option<SolverKind>,
    /// True when a cluster solve lost a worker mid-solve and had to
    /// re-dispatch its shards to survivors
    /// ([`crate::cluster::ClusterSolveOutcome::resharded`]).
    pub resharded: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_key_shared_arc() {
        let mut rng = Rng::seed(1);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let r1 = SolveRequest::new(1, x.clone(), vec![0.0; 4]);
        let r2 = SolveRequest::new(2, x.clone(), vec![1.0; 4]);
        assert_eq!(r1.matrix_key(), r2.matrix_key());
        let x2 = Arc::new(Mat::randn(&mut rng, 4, 2));
        let r3 = SolveRequest::new(3, x2, vec![0.0; 4]);
        assert_ne!(r1.matrix_key(), r3.matrix_key());
    }

    #[test]
    fn job_single() {
        let mut rng = Rng::seed(2);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let job = SolveJob::single(SolveRequest::new(7, x, vec![0.0; 4]));
        assert_eq!(job.len(), 1);
        assert_eq!(job.members[0].0, 7);
        assert!(!job.is_empty());
    }

    #[test]
    fn sparse_requests_share_keys_like_dense_ones() {
        let mut b = crate::sparse::CooBuilder::new(4, 2);
        b.push(0, 0, 1.0);
        b.push(3, 1, 2.0);
        let s = Arc::new(b.to_csc());
        let r1 = SolveRequest::builder(1, s.clone(), vec![0.0; 4]).build();
        let r2 = SolveRequest::builder(2, s.clone(), vec![1.0; 4]).build();
        assert_eq!(r1.matrix_key(), r2.matrix_key());
        assert!(r1.x.is_sparse());
        assert_eq!(r1.x.shape(), (4, 2));
        assert_eq!(r1.x.matrix_ref().nnz(), 2);
        // A dense request over an equal-shape matrix gets a distinct key.
        let mut rng = Rng::seed(9);
        let d = Arc::new(Mat::randn(&mut rng, 4, 2));
        let r3 = SolveRequest::new(3, d, vec![0.0; 4]);
        assert_ne!(r1.matrix_key(), r3.matrix_key());
        assert!(!r3.x.is_sparse());
    }

    #[test]
    fn builder_defaults_match_with_matrix() {
        let mut rng = Rng::seed(3);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let r = SolveRequest::builder(9, x, vec![0.0; 4]).build();
        assert_eq!(r.id, 9);
        assert_eq!(r.backend, SolverKind::Auto);
        assert!(r.trace.is_none());
        assert!(r.deadline_ms.is_none());
        assert!(!r.degraded);
        assert!(r.job_id.is_none());
        assert!(!r.escalate);
        assert!(!r.opts.cancel.is_enabled());
    }

    #[test]
    fn builder_sets_every_knob() {
        let mut rng = Rng::seed(4);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let mut opts = SolveOptions::default();
        opts.max_sweeps = 7;
        let r = SolveRequest::builder(5, x, vec![1.0; 4])
            .opts(opts)
            .backend(SolverKind::Bak)
            .deadline_ms(250)
            .trace(true)
            .job_id("job-1")
            .escalate(true)
            .build();
        assert_eq!(r.opts.max_sweeps, 7);
        assert_eq!(r.backend, SolverKind::Bak);
        assert_eq!(r.deadline_ms, Some(250));
        assert!(r.trace.is_some());
        assert_eq!(r.job_id.as_deref(), Some("job-1"));
        assert!(r.escalate);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructors_still_work() {
        let mut b = crate::sparse::CooBuilder::new(4, 2);
        b.push(0, 0, 1.0);
        let s = Arc::new(b.to_csc());
        let r = SolveRequest::new_sparse(1, s, vec![0.0; 4]);
        assert!(r.x.is_sparse());
        let mut rng = Rng::seed(5);
        let d = Arc::new(Mat::randn(&mut rng, 4, 2));
        let t = SolveRequest::new(2, d, vec![0.0; 4]).traced();
        assert!(t.trace.is_some());
    }

    #[test]
    fn degraded_flag_propagates_to_job() {
        let mut rng = Rng::seed(6);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let mut r = SolveRequest::builder(1, x, vec![0.0; 4]).build();
        r.degraded = true;
        let job = SolveJob::single(r);
        assert!(job.degraded);
    }

    #[test]
    fn durability_knobs_propagate_to_job() {
        let mut rng = Rng::seed(7);
        let x = Arc::new(Mat::randn(&mut rng, 4, 2));
        let r = SolveRequest::builder(1, x, vec![0.0; 4])
            .job_id("ckpt-key")
            .escalate(true)
            .build();
        let job = SolveJob::single(r);
        assert_eq!(job.job_id.as_deref(), Some("ckpt-key"));
        assert!(job.escalate);
    }
}
