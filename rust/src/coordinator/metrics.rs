//! Service metrics: counters and latency histograms, JSON-dumpable.
//!
//! Lock-free counters (atomics); histograms use coarse log-scale buckets
//! so recording is a single atomic increment on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::api::SolverKind;
use crate::parallel::PoolStats;
use crate::util::json::{Json, ObjBuilder};

/// Log-bucketed latency histogram: bucket i covers
/// [10^(i/4 - 7), 10^((i+1)/4 - 7)) seconds, i.e. 100ns .. ~1000s.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const NBUCKETS: usize = 40;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        if seconds <= 0.0 {
            return 0;
        }
        let idx = ((seconds.log10() + 7.0) * 4.0).floor();
        idx.clamp(0.0, (NBUCKETS - 1) as f64) as usize
    }

    /// Record one observation.
    pub fn record(&self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / c as f64
        }
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 10f64.powf((i + 1) as f64 / 4.0 - 7.0);
            }
        }
        10f64.powf(NBUCKETS as f64 / 4.0 - 7.0)
    }
}

/// All coordinator metrics.
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    pub jobs_run: AtomicU64,
    pub batched_members: AtomicU64,
    pub queue_rejections: AtomicU64,
    /// Sparse jobs that ran on a backend without a native sparse path and
    /// were densified before execution.
    pub densified_jobs: AtomicU64,
    /// Gauge: jobs currently sitting in the job queue (scheduled but not
    /// yet picked up by a worker).
    pub job_queue_depth: AtomicU64,
    /// Chunks read from disk by streaming (file-backed) jobs.
    pub stream_chunks_read: AtomicU64,
    /// Bytes read from disk by streaming jobs.
    pub stream_bytes_read: AtomicU64,
    /// Times a streaming consumer blocked waiting on the prefetch thread
    /// (high values mean the job is IO-bound at the configured budget).
    pub stream_buffer_stalls: AtomicU64,
    /// Jobs executed per backend, indexed in [`SolverKind::CONCRETE`]
    /// order (the backend that actually ran, post-routing).
    backend_jobs: [AtomicU64; SolverKind::CONCRETE.len()],
    /// Worker-pool gauges ([`crate::parallel::PoolStats`]): attached by
    /// the service at startup, exported alongside the counters.
    pool: OnceLock<Arc<PoolStats>>,
    pub solve_latency: Histogram,
    pub queue_wait: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            jobs_run: AtomicU64::new(0),
            batched_members: AtomicU64::new(0),
            queue_rejections: AtomicU64::new(0),
            densified_jobs: AtomicU64::new(0),
            job_queue_depth: AtomicU64::new(0),
            stream_chunks_read: AtomicU64::new(0),
            stream_bytes_read: AtomicU64::new(0),
            stream_buffer_stalls: AtomicU64::new(0),
            backend_jobs: std::array::from_fn(|_| AtomicU64::new(0)),
            pool: OnceLock::new(),
            solve_latency: Histogram::new(),
            queue_wait: Histogram::new(),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one executed job against the backend that ran it (`Auto`
    /// never reaches execution, so non-concrete kinds are ignored).
    pub fn record_backend_job(&self, kind: SolverKind) {
        if let Some(i) = SolverKind::CONCRETE.iter().position(|&k| k == kind) {
            self.backend_jobs[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Attach the worker pool's gauges (once, at service startup).
    pub fn attach_pool(&self, stats: Arc<PoolStats>) {
        let _ = self.pool.set(stats);
    }

    /// The attached pool gauges, when a pool is running.
    pub fn pool(&self) -> Option<&Arc<PoolStats>> {
        self.pool.get()
    }

    /// Executed-job count for one backend.
    pub fn backend_jobs(&self, kind: SolverKind) -> u64 {
        SolverKind::CONCRETE
            .iter()
            .position(|&k| k == kind)
            .map(|i| self.backend_jobs[i].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Serialize a snapshot to JSON.
    pub fn to_json(&self) -> Json {
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut per_backend = ObjBuilder::new();
        for (i, &kind) in SolverKind::CONCRETE.iter().enumerate() {
            per_backend =
                per_backend.num(kind.as_str(), self.backend_jobs[i].load(Ordering::Relaxed) as f64);
        }
        // Pool gauges: zeros when no pool is attached (metrics created
        // standalone), live values while the service runs.
        let (workers, busy, inflight, panicked, worker_jobs) = match self.pool.get() {
            Some(p) => (
                p.workers() as f64,
                p.workers_busy.load(Ordering::Relaxed) as f64,
                p.jobs_inflight.load(Ordering::Relaxed) as f64,
                p.jobs_panicked.load(Ordering::Relaxed) as f64,
                p.worker_jobs(),
            ),
            None => (0.0, 0.0, 0.0, 0.0, Vec::new()),
        };
        let worker_jobs =
            Json::Arr(worker_jobs.iter().map(|&v| Json::Num(v as f64)).collect());
        ObjBuilder::new()
            .num("requests_submitted", c(&self.requests_submitted))
            .num("requests_completed", c(&self.requests_completed))
            .num("requests_failed", c(&self.requests_failed))
            .num("jobs_run", c(&self.jobs_run))
            .num("batched_members", c(&self.batched_members))
            .num("queue_rejections", c(&self.queue_rejections))
            .num("densified_jobs", c(&self.densified_jobs))
            .num("job_queue_depth", c(&self.job_queue_depth))
            .num("stream_chunks_read", c(&self.stream_chunks_read))
            .num("stream_bytes_read", c(&self.stream_bytes_read))
            .num("stream_buffer_stalls", c(&self.stream_buffer_stalls))
            .num("workers", workers)
            .num("workers_busy", busy)
            .num("jobs_inflight", inflight)
            .num("worker_panics", panicked)
            .val("worker_jobs", worker_jobs)
            .val("backend_jobs", per_backend.build())
            .num("solve_latency_mean_s", self.solve_latency.mean())
            .num("solve_latency_p50_s", self.solve_latency.quantile(0.5))
            .num("solve_latency_p99_s", self.solve_latency.quantile(0.99))
            .num("queue_wait_mean_s", self.queue_wait.mean())
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_count_and_mean() {
        let h = Histogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-3 && p50 < 1e-2, "p50={p50}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn bucket_of_extremes() {
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(-1.0), 0);
        assert_eq!(Histogram::bucket_of(1e9), NBUCKETS - 1);
    }

    #[test]
    fn metrics_json_has_fields() {
        let m = Metrics::new();
        m.requests_submitted.store(5, Ordering::Relaxed);
        m.solve_latency.record(0.01);
        let j = m.to_json();
        assert_eq!(j.get("requests_submitted").unwrap().as_f64(), Some(5.0));
        assert!(j.get("solve_latency_mean_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn sparse_and_queue_fields_exported() {
        let m = Metrics::new();
        m.densified_jobs.store(3, Ordering::Relaxed);
        m.job_queue_depth.store(2, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("densified_jobs").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("job_queue_depth").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn stream_counters_exported() {
        let m = Metrics::new();
        m.stream_chunks_read.store(7, Ordering::Relaxed);
        m.stream_bytes_read.store(4096, Ordering::Relaxed);
        m.stream_buffer_stalls.store(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("stream_chunks_read").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("stream_bytes_read").unwrap().as_f64(), Some(4096.0));
        assert_eq!(j.get("stream_buffer_stalls").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn pool_gauges_zero_until_attached_then_live() {
        let m = Metrics::new();
        let j = m.to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("workers_busy").unwrap().as_f64(), Some(0.0));
        assert!(j.get("worker_jobs").unwrap().items().is_empty());

        let pool = crate::parallel::Executor::start("m", 2, 4, |_w, _j: ()| {});
        m.attach_pool(pool.stats());
        pool.submit(()).unwrap();
        pool.submit(()).unwrap();
        pool.shutdown();
        let j = m.to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("jobs_inflight").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("worker_panics").unwrap().as_f64(), Some(0.0));
        let per_worker = j.get("worker_jobs").unwrap().items();
        assert_eq!(per_worker.len(), 2);
        let total: f64 = per_worker.iter().filter_map(|v| v.as_f64()).sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn per_backend_job_counts() {
        let m = Metrics::new();
        m.record_backend_job(SolverKind::Bak);
        m.record_backend_job(SolverKind::Bak);
        m.record_backend_job(SolverKind::Qr);
        m.record_backend_job(SolverKind::Auto); // ignored: never executes
        assert_eq!(m.backend_jobs(SolverKind::Bak), 2);
        assert_eq!(m.backend_jobs(SolverKind::Qr), 1);
        assert_eq!(m.backend_jobs(SolverKind::Cgls), 0);
        assert_eq!(m.backend_jobs(SolverKind::Auto), 0);
        let j = m.to_json();
        let per = j.get("backend_jobs").expect("nested backend_jobs object");
        assert_eq!(per.get("bak").unwrap().as_f64(), Some(2.0));
        assert_eq!(per.get("qr").unwrap().as_f64(), Some(1.0));
        // Every concrete kind is present even at zero.
        for kind in SolverKind::CONCRETE {
            assert!(per.get(kind.as_str()).is_some(), "{kind} missing");
        }
    }
}
