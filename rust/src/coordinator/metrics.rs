//! Service metrics: counters and latency histograms, JSON-dumpable.
//!
//! Lock-free counters (atomics); histograms use coarse log-scale buckets
//! so recording is a single atomic increment on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::api::SolverKind;
use crate::parallel::PoolStats;
use crate::util::json::{Json, ObjBuilder};

/// Log-bucketed latency histogram: bucket i covers
/// [10^(i/4 - 7), 10^((i+1)/4 - 7)) seconds, i.e. 100ns .. ~1000s.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const NBUCKETS: usize = 40;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        if seconds <= 0.0 {
            return 0;
        }
        let idx = ((seconds.log10() + 7.0) * 4.0).floor();
        idx.clamp(0.0, (NBUCKETS - 1) as f64) as usize
    }

    /// Upper edge (seconds) of bucket `i` — the `le` bound Prometheus
    /// exposition publishes for it.
    pub fn bucket_upper_edge(i: usize) -> f64 {
        10f64.powf((i + 1) as f64 / 4.0 - 7.0)
    }

    /// Snapshot of the per-bucket counts, in bucket order.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Record one observation. Non-finite or negative durations clamp to
    /// zero (bucket 0) instead of poisoning the running sum; the sum
    /// saturates at `u64::MAX` ns rather than wrapping.
    pub fn record(&self, seconds: f64) {
        let s = if seconds.is_finite() && seconds > 0.0 { seconds } else { 0.0 };
        self.buckets[Self::bucket_of(s)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // f64→u64 casts already saturate (and map NaN to 0), but the CAS
        // loop is what keeps the *accumulated* sum from wrapping.
        let ns = (s * 1e9).min(u64::MAX as f64) as u64;
        let mut cur = self.sum_ns.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(ns);
            match self.sum_ns.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total observed time in seconds (saturating, see [`Histogram::record`]).
    pub fn sum_seconds(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Mean in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / c as f64
        }
    }

    /// Approximate quantile: log-space interpolation within the bucket
    /// containing the target rank (observations inside a bucket are
    /// assumed log-uniform, matching the log-scale bucket layout). The
    /// old upper-edge answer biased every quantile high by up to one
    /// bucket width (10^0.25 ≈ 1.78×).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 && seen + n >= target {
                let frac = (target - seen) as f64 / n as f64;
                return 10f64.powf(i as f64 / 4.0 - 7.0 + frac * 0.25);
            }
            seen += n;
        }
        Self::bucket_upper_edge(NBUCKETS - 1)
    }
}

/// All coordinator metrics.
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    pub jobs_run: AtomicU64,
    pub batched_members: AtomicU64,
    pub queue_rejections: AtomicU64,
    /// Sparse jobs that ran on a backend without a native sparse path and
    /// were densified before execution.
    pub densified_jobs: AtomicU64,
    /// Requests rejected by admission control (saturated gate, no
    /// degraded mode) with a structured `overloaded` error.
    pub jobs_shed: AtomicU64,
    /// Requests whose deadline expired (queued or mid-solve); clients got
    /// [`crate::api::SolverError::DeadlineExceeded`] with best-so-far.
    pub jobs_deadline_exceeded: AtomicU64,
    /// Client retry attempts observed by the server (`attempt > 0`).
    pub retries_attempted: AtomicU64,
    /// Requests answered in degraded mode (reduced-sweep BAK) instead of
    /// being shed.
    pub degraded_solves: AtomicU64,
    /// Backend-ladder escalation attempts (numerical breakdown with
    /// `escalate` set re-runs on the next rung: BAK → CGLS → QR).
    pub escalations: AtomicU64,
    /// `.ckpt` snapshots written by durable (`job_id`-carrying) jobs.
    pub checkpoints_written: AtomicU64,
    /// Durable jobs that warm-started from a journal checkpoint.
    pub resumes: AtomicU64,
    /// Requests that failed on a `.sbck` chunk whose CRC32 did not match
    /// ([`crate::api::SolverError::CorruptData`]).
    pub corrupt_chunks: AtomicU64,
    /// Gauge: jobs currently sitting in the job queue (scheduled but not
    /// yet picked up by a worker).
    pub job_queue_depth: AtomicU64,
    /// Chunks read from disk by streaming (file-backed) jobs.
    pub stream_chunks_read: AtomicU64,
    /// Bytes read from disk by streaming jobs.
    pub stream_bytes_read: AtomicU64,
    /// Times a streaming consumer blocked waiting on the prefetch thread
    /// (high values mean the job is IO-bound at the configured budget).
    pub stream_buffer_stalls: AtomicU64,
    /// Shard dispatches sent to cluster workers (one per shard per sync
    /// round; re-dispatches after a worker loss count again).
    pub shards_dispatched: AtomicU64,
    /// Shards re-dispatched to a surviving worker after a worker died
    /// mid-solve (each also flips the outcome's `resharded` flag).
    pub reshards: AtomicU64,
    /// Global sync rounds completed by cluster solves (one mass-weighted
    /// merge each).
    pub sync_rounds: AtomicU64,
    /// Gauge: cluster workers currently alive in the membership view
    /// (0 when no cluster is configured).
    pub cluster_workers: AtomicU64,
    /// Jobs executed per backend, indexed in [`SolverKind::CONCRETE`]
    /// order (the backend that actually ran, post-routing).
    backend_jobs: [AtomicU64; SolverKind::CONCRETE.len()],
    /// Worker-pool gauges ([`crate::parallel::PoolStats`]): attached by
    /// the service at startup, exported alongside the counters.
    pool: OnceLock<Arc<PoolStats>>,
    pub solve_latency: Histogram,
    pub queue_wait: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            jobs_run: AtomicU64::new(0),
            batched_members: AtomicU64::new(0),
            queue_rejections: AtomicU64::new(0),
            densified_jobs: AtomicU64::new(0),
            jobs_shed: AtomicU64::new(0),
            jobs_deadline_exceeded: AtomicU64::new(0),
            retries_attempted: AtomicU64::new(0),
            degraded_solves: AtomicU64::new(0),
            escalations: AtomicU64::new(0),
            checkpoints_written: AtomicU64::new(0),
            resumes: AtomicU64::new(0),
            corrupt_chunks: AtomicU64::new(0),
            job_queue_depth: AtomicU64::new(0),
            stream_chunks_read: AtomicU64::new(0),
            stream_bytes_read: AtomicU64::new(0),
            stream_buffer_stalls: AtomicU64::new(0),
            shards_dispatched: AtomicU64::new(0),
            reshards: AtomicU64::new(0),
            sync_rounds: AtomicU64::new(0),
            cluster_workers: AtomicU64::new(0),
            backend_jobs: std::array::from_fn(|_| AtomicU64::new(0)),
            pool: OnceLock::new(),
            solve_latency: Histogram::new(),
            queue_wait: Histogram::new(),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one executed job against the backend that ran it (`Auto`
    /// never reaches execution, so non-concrete kinds are ignored).
    pub fn record_backend_job(&self, kind: SolverKind) {
        if let Some(i) = SolverKind::CONCRETE.iter().position(|&k| k == kind) {
            self.backend_jobs[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Attach the worker pool's gauges (once, at service startup).
    pub fn attach_pool(&self, stats: Arc<PoolStats>) {
        let _ = self.pool.set(stats);
    }

    /// The attached pool gauges, when a pool is running.
    pub fn pool(&self) -> Option<&Arc<PoolStats>> {
        self.pool.get()
    }

    /// Executed-job count for one backend.
    pub fn backend_jobs(&self, kind: SolverKind) -> u64 {
        SolverKind::CONCRETE
            .iter()
            .position(|&k| k == kind)
            .map(|i| self.backend_jobs[i].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Serialize a snapshot to JSON.
    pub fn to_json(&self) -> Json {
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut per_backend = ObjBuilder::new();
        for (i, &kind) in SolverKind::CONCRETE.iter().enumerate() {
            per_backend =
                per_backend.num(kind.as_str(), self.backend_jobs[i].load(Ordering::Relaxed) as f64);
        }
        // Pool gauges: zeros when no pool is attached (metrics created
        // standalone), live values while the service runs.
        let (workers, busy, inflight, panicked, worker_jobs) = match self.pool.get() {
            Some(p) => (
                p.workers() as f64,
                p.workers_busy.load(Ordering::Relaxed) as f64,
                p.jobs_inflight.load(Ordering::Relaxed) as f64,
                p.jobs_panicked.load(Ordering::Relaxed) as f64,
                p.worker_jobs(),
            ),
            None => (0.0, 0.0, 0.0, 0.0, Vec::new()),
        };
        let worker_jobs =
            Json::Arr(worker_jobs.iter().map(|&v| Json::Num(v as f64)).collect());
        ObjBuilder::new()
            .num("requests_submitted", c(&self.requests_submitted))
            .num("requests_completed", c(&self.requests_completed))
            .num("requests_failed", c(&self.requests_failed))
            .num("jobs_run", c(&self.jobs_run))
            .num("batched_members", c(&self.batched_members))
            .num("queue_rejections", c(&self.queue_rejections))
            .num("densified_jobs", c(&self.densified_jobs))
            .num("jobs_shed", c(&self.jobs_shed))
            .num("jobs_deadline_exceeded", c(&self.jobs_deadline_exceeded))
            .num("retries_attempted", c(&self.retries_attempted))
            .num("degraded_solves", c(&self.degraded_solves))
            .num("escalations", c(&self.escalations))
            .num("checkpoints_written", c(&self.checkpoints_written))
            .num("resumes", c(&self.resumes))
            .num("corrupt_chunks", c(&self.corrupt_chunks))
            .num("job_queue_depth", c(&self.job_queue_depth))
            .num("stream_chunks_read", c(&self.stream_chunks_read))
            .num("stream_bytes_read", c(&self.stream_bytes_read))
            .num("stream_buffer_stalls", c(&self.stream_buffer_stalls))
            .num("shards_dispatched", c(&self.shards_dispatched))
            .num("reshards", c(&self.reshards))
            .num("sync_rounds", c(&self.sync_rounds))
            .num("cluster_workers", c(&self.cluster_workers))
            .num("workers", workers)
            .num("workers_busy", busy)
            .num("jobs_inflight", inflight)
            .num("worker_panics", panicked)
            .val("worker_jobs", worker_jobs)
            .val("backend_jobs", per_backend.build())
            .num("solve_latency_mean_s", self.solve_latency.mean())
            .num("solve_latency_p50_s", self.solve_latency.quantile(0.5))
            .num("solve_latency_p99_s", self.solve_latency.quantile(0.99))
            .num("solve_latency_count", self.solve_latency.count() as f64)
            .num("queue_wait_mean_s", self.queue_wait.mean())
            .num("queue_wait_p50_s", self.queue_wait.quantile(0.5))
            .num("queue_wait_p99_s", self.queue_wait.quantile(0.99))
            .num("queue_wait_count", self.queue_wait.count() as f64)
            .build()
    }

    /// Serialize a snapshot in the Prometheus text exposition format
    /// (v0.0.4): counters as `_total`, gauges bare, histograms as
    /// cumulative `_bucket{le=...}` series plus `_sum`/`_count`. All
    /// metric names carry the `pallas_` prefix.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut counter = |out: &mut String, name: &str, v: u64| {
            out.push_str(&format!(
                "# TYPE pallas_{name}_total counter\npallas_{name}_total {v}\n"
            ));
        };
        counter(&mut out, "requests_submitted", c(&self.requests_submitted));
        counter(&mut out, "requests_completed", c(&self.requests_completed));
        counter(&mut out, "requests_failed", c(&self.requests_failed));
        counter(&mut out, "jobs_run", c(&self.jobs_run));
        counter(&mut out, "batched_members", c(&self.batched_members));
        counter(&mut out, "queue_rejections", c(&self.queue_rejections));
        counter(&mut out, "densified_jobs", c(&self.densified_jobs));
        counter(&mut out, "jobs_shed", c(&self.jobs_shed));
        counter(&mut out, "jobs_deadline_exceeded", c(&self.jobs_deadline_exceeded));
        counter(&mut out, "retries_attempted", c(&self.retries_attempted));
        counter(&mut out, "degraded_solves", c(&self.degraded_solves));
        counter(&mut out, "escalations", c(&self.escalations));
        counter(&mut out, "checkpoints_written", c(&self.checkpoints_written));
        counter(&mut out, "resumes", c(&self.resumes));
        counter(&mut out, "corrupt_chunks", c(&self.corrupt_chunks));
        counter(&mut out, "stream_chunks_read", c(&self.stream_chunks_read));
        counter(&mut out, "stream_bytes_read", c(&self.stream_bytes_read));
        counter(&mut out, "stream_buffer_stalls", c(&self.stream_buffer_stalls));
        counter(&mut out, "shards_dispatched", c(&self.shards_dispatched));
        counter(&mut out, "reshards", c(&self.reshards));
        counter(&mut out, "sync_rounds", c(&self.sync_rounds));

        out.push_str("# TYPE pallas_backend_jobs_total counter\n");
        for (i, &kind) in SolverKind::CONCRETE.iter().enumerate() {
            out.push_str(&format!(
                "pallas_backend_jobs_total{{backend=\"{}\"}} {}\n",
                kind.as_str(),
                self.backend_jobs[i].load(Ordering::Relaxed)
            ));
        }

        let mut gauge = |out: &mut String, name: &str, v: f64| {
            out.push_str(&format!("# TYPE pallas_{name} gauge\npallas_{name} {v}\n"));
        };
        gauge(&mut out, "job_queue_depth", c(&self.job_queue_depth) as f64);
        gauge(&mut out, "cluster_workers", c(&self.cluster_workers) as f64);
        let (workers, busy, inflight, panicked) = match self.pool.get() {
            Some(p) => (
                p.workers() as f64,
                p.workers_busy.load(Ordering::Relaxed) as f64,
                p.jobs_inflight.load(Ordering::Relaxed) as f64,
                p.jobs_panicked.load(Ordering::Relaxed) as f64,
            ),
            None => (0.0, 0.0, 0.0, 0.0),
        };
        gauge(&mut out, "workers", workers);
        gauge(&mut out, "workers_busy", busy);
        gauge(&mut out, "jobs_inflight", inflight);
        gauge(&mut out, "worker_panics", panicked);

        let histogram = |out: &mut String, name: &str, h: &Histogram| {
            out.push_str(&format!("# TYPE pallas_{name}_seconds histogram\n"));
            let mut cum = 0u64;
            for (i, n) in h.bucket_counts().into_iter().enumerate() {
                cum += n;
                out.push_str(&format!(
                    "pallas_{name}_seconds_bucket{{le=\"{:e}\"}} {cum}\n",
                    Histogram::bucket_upper_edge(i)
                ));
            }
            out.push_str(&format!(
                "pallas_{name}_seconds_bucket{{le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!(
                "pallas_{name}_seconds_sum {}\n",
                h.sum_seconds()
            ));
            out.push_str(&format!("pallas_{name}_seconds_count {}\n", h.count()));
        };
        histogram(&mut out, "solve_latency", &self.solve_latency);
        histogram(&mut out, "queue_wait", &self.queue_wait);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_count_and_mean() {
        let h = Histogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn histogram_quantile_monotone_and_interpolated() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // True p50 is ~5.0e-3. In-bucket interpolation must land within
        // one bucket width (10^0.25 ≈ 1.78×) of it — the old upper-edge
        // answer could be a full bucket high.
        let true_p50 = 5.0e-3;
        let width = 10f64.powf(0.25);
        assert!(
            p50 > true_p50 / width && p50 < true_p50 * width,
            "p50={p50} not within a bucket width of {true_p50}"
        );
    }

    #[test]
    fn histogram_interpolates_within_a_single_bucket() {
        // All mass in one bucket: quantiles must spread across the bucket
        // instead of all collapsing to its upper edge.
        let h = Histogram::new();
        for _ in 0..1000 {
            h.record(2e-3); // bucket [1.78e-3, 3.16e-3)
        }
        let p10 = h.quantile(0.10);
        let p90 = h.quantile(0.90);
        assert!(p10 < p90, "p10={p10} p90={p90}");
        let lo = 10f64.powf(-11.0 / 4.0); // bucket lower edge
        let hi = 10f64.powf(-10.0 / 4.0); // bucket upper edge
        assert!(p10 >= lo && p90 <= hi, "quantiles escaped the bucket");
    }

    #[test]
    fn record_clamps_pathological_inputs() {
        let h = Histogram::new();
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3);
        // NaN and negative clamp to 0s; +Inf clamps to the u64 ns ceiling
        // — the mean stays finite either way.
        assert!(h.mean().is_finite());
        assert_eq!(h.bucket_counts()[0], 2);
    }

    #[test]
    fn record_sum_saturates_instead_of_wrapping() {
        let h = Histogram::new();
        // Two observations that each saturate the ns sum on their own:
        // a wrapping add would land near zero and wreck the mean.
        h.record(1e30);
        h.record(1e30);
        assert_eq!(h.count(), 2);
        let expected = u64::MAX as f64 / 1e9 / 2.0;
        assert!(
            (h.mean() - expected).abs() / expected < 1e-9,
            "mean={} should sit at the saturation ceiling",
            h.mean()
        );
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn bucket_of_extremes() {
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(-1.0), 0);
        assert_eq!(Histogram::bucket_of(1e9), NBUCKETS - 1);
    }

    #[test]
    fn metrics_json_has_fields() {
        let m = Metrics::new();
        m.requests_submitted.store(5, Ordering::Relaxed);
        m.solve_latency.record(0.01);
        let j = m.to_json();
        assert_eq!(j.get("requests_submitted").unwrap().as_f64(), Some(5.0));
        assert!(j.get("solve_latency_mean_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn json_exports_full_quartet_for_both_histograms() {
        let m = Metrics::new();
        m.solve_latency.record(0.01);
        m.solve_latency.record(0.02);
        m.queue_wait.record(0.001);
        let j = m.to_json();
        for key in [
            "solve_latency_mean_s",
            "solve_latency_p50_s",
            "solve_latency_p99_s",
            "solve_latency_count",
            "queue_wait_mean_s",
            "queue_wait_p50_s",
            "queue_wait_p99_s",
            "queue_wait_count",
        ] {
            let v = j.get(key).unwrap_or_else(|| panic!("{key} missing")).as_f64().unwrap();
            assert!(v > 0.0, "{key}={v}");
        }
        assert_eq!(j.get("solve_latency_count").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("queue_wait_count").unwrap().as_f64(), Some(1.0));
    }

    /// The obs-smoke CI job runs this format checker: every sample's
    /// metric family is declared with `# TYPE`, histogram buckets are
    /// cumulative, and every histogram closes with `+Inf`/`_sum`/`_count`.
    #[test]
    fn prometheus_exposition_well_formed() {
        let m = Metrics::new();
        m.requests_submitted.store(7, Ordering::Relaxed);
        m.record_backend_job(SolverKind::Bak);
        m.solve_latency.record(0.004);
        m.solve_latency.record(0.04);
        m.queue_wait.record(0.0001);
        let text = m.to_prometheus();

        let mut declared: Vec<String> = Vec::new();
        for line in text.lines() {
            assert!(!line.trim().is_empty(), "no blank lines in exposition");
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let fam = it.next().unwrap().to_string();
                let kind = it.next().unwrap();
                assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{line}");
                assert!(!declared.contains(&fam), "family {fam} declared twice");
                declared.push(fam);
                continue;
            }
            // Sample line: name{labels} value — its family must have been
            // declared. Histogram samples belong to the base family.
            let name = line.split(['{', ' ']).next().unwrap();
            let fam = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            assert!(
                declared.iter().any(|d| d == fam || d == name),
                "sample {name} has no # TYPE declaration"
            );
            assert!(name.starts_with("pallas_"), "unprefixed metric {name}");
            let value = line.rsplit(' ').next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line}");
        }

        for hist in ["pallas_solve_latency_seconds", "pallas_queue_wait_seconds"] {
            let buckets: Vec<u64> = text
                .lines()
                .filter(|l| l.starts_with(&format!("{hist}_bucket")) && !l.contains("+Inf"))
                .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
                .collect();
            assert_eq!(buckets.len(), 40, "{hist} bucket series");
            assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "{hist} not cumulative");
            let inf: u64 = text
                .lines()
                .find(|l| l.starts_with(&format!("{hist}_bucket{{le=\"+Inf\"}}")))
                .expect("+Inf bucket")
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap();
            let count: u64 = text
                .lines()
                .find(|l| l.starts_with(&format!("{hist}_count")))
                .expect("_count sample")
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap();
            assert_eq!(inf, count, "{hist}: +Inf bucket must equal _count");
            assert_eq!(*buckets.last().unwrap(), count, "last bucket must reach _count");
            assert!(
                text.lines().any(|l| l.starts_with(&format!("{hist}_sum"))),
                "{hist}_sum missing"
            );
        }
        assert!(text.contains("pallas_backend_jobs_total{backend=\"bak\"} 1"));
    }

    #[test]
    fn sparse_and_queue_fields_exported() {
        let m = Metrics::new();
        m.densified_jobs.store(3, Ordering::Relaxed);
        m.job_queue_depth.store(2, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("densified_jobs").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("job_queue_depth").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn robustness_counters_exported() {
        let m = Metrics::new();
        m.jobs_shed.store(2, Ordering::Relaxed);
        m.jobs_deadline_exceeded.store(1, Ordering::Relaxed);
        m.retries_attempted.store(4, Ordering::Relaxed);
        m.degraded_solves.store(3, Ordering::Relaxed);
        m.escalations.store(5, Ordering::Relaxed);
        m.checkpoints_written.store(6, Ordering::Relaxed);
        m.resumes.store(7, Ordering::Relaxed);
        m.corrupt_chunks.store(8, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("jobs_shed").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("jobs_deadline_exceeded").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("retries_attempted").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("degraded_solves").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("escalations").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("checkpoints_written").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("resumes").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("corrupt_chunks").unwrap().as_f64(), Some(8.0));
        let text = m.to_prometheus();
        assert!(text.contains("pallas_jobs_shed_total 2"));
        assert!(text.contains("pallas_jobs_deadline_exceeded_total 1"));
        assert!(text.contains("pallas_retries_attempted_total 4"));
        assert!(text.contains("pallas_degraded_solves_total 3"));
        assert!(text.contains("pallas_escalations_total 5"));
        assert!(text.contains("pallas_checkpoints_written_total 6"));
        assert!(text.contains("pallas_resumes_total 7"));
        assert!(text.contains("pallas_corrupt_chunks_total 8"));
    }

    #[test]
    fn cluster_counters_exported() {
        let m = Metrics::new();
        m.shards_dispatched.store(12, Ordering::Relaxed);
        m.reshards.store(2, Ordering::Relaxed);
        m.sync_rounds.store(6, Ordering::Relaxed);
        m.cluster_workers.store(3, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("shards_dispatched").unwrap().as_f64(), Some(12.0));
        assert_eq!(j.get("reshards").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("sync_rounds").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("cluster_workers").unwrap().as_f64(), Some(3.0));
        let text = m.to_prometheus();
        assert!(text.contains("pallas_shards_dispatched_total 12"));
        assert!(text.contains("pallas_reshards_total 2"));
        assert!(text.contains("pallas_sync_rounds_total 6"));
        assert!(text.contains("# TYPE pallas_cluster_workers gauge\npallas_cluster_workers 3"));
    }

    #[test]
    fn stream_counters_exported() {
        let m = Metrics::new();
        m.stream_chunks_read.store(7, Ordering::Relaxed);
        m.stream_bytes_read.store(4096, Ordering::Relaxed);
        m.stream_buffer_stalls.store(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("stream_chunks_read").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("stream_bytes_read").unwrap().as_f64(), Some(4096.0));
        assert_eq!(j.get("stream_buffer_stalls").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn pool_gauges_zero_until_attached_then_live() {
        let m = Metrics::new();
        let j = m.to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("workers_busy").unwrap().as_f64(), Some(0.0));
        assert!(j.get("worker_jobs").unwrap().items().is_empty());

        let pool = crate::parallel::Executor::start("m", 2, 4, |_w, _j: ()| {});
        m.attach_pool(pool.stats());
        pool.submit(()).unwrap();
        pool.submit(()).unwrap();
        pool.shutdown();
        let j = m.to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("jobs_inflight").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("worker_panics").unwrap().as_f64(), Some(0.0));
        let per_worker = j.get("worker_jobs").unwrap().items();
        assert_eq!(per_worker.len(), 2);
        let total: f64 = per_worker.iter().filter_map(|v| v.as_f64()).sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn per_backend_job_counts() {
        let m = Metrics::new();
        m.record_backend_job(SolverKind::Bak);
        m.record_backend_job(SolverKind::Bak);
        m.record_backend_job(SolverKind::Qr);
        m.record_backend_job(SolverKind::Auto); // ignored: never executes
        assert_eq!(m.backend_jobs(SolverKind::Bak), 2);
        assert_eq!(m.backend_jobs(SolverKind::Qr), 1);
        assert_eq!(m.backend_jobs(SolverKind::Cgls), 0);
        assert_eq!(m.backend_jobs(SolverKind::Auto), 0);
        let j = m.to_json();
        let per = j.get("backend_jobs").expect("nested backend_jobs object");
        assert_eq!(per.get("bak").unwrap().as_f64(), Some(2.0));
        assert_eq!(per.get("qr").unwrap().as_f64(), Some(1.0));
        // Every concrete kind is present even at zero.
        for kind in SolverKind::CONCRETE {
            assert!(per.get(kind.as_str()).is_some(), "{kind} missing");
        }
    }
}
