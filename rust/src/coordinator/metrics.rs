//! Service metrics: counters and latency histograms, JSON-dumpable.
//!
//! Lock-free counters (atomics); histograms use coarse log-scale buckets
//! so recording is a single atomic increment on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{Json, ObjBuilder};

/// Log-bucketed latency histogram: bucket i covers
/// [10^(i/4 - 7), 10^((i+1)/4 - 7)) seconds, i.e. 100ns .. ~1000s.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const NBUCKETS: usize = 40;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        if seconds <= 0.0 {
            return 0;
        }
        let idx = ((seconds.log10() + 7.0) * 4.0).floor();
        idx.clamp(0.0, (NBUCKETS - 1) as f64) as usize
    }

    /// Record one observation.
    pub fn record(&self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / c as f64
        }
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 10f64.powf((i + 1) as f64 / 4.0 - 7.0);
            }
        }
        10f64.powf(NBUCKETS as f64 / 4.0 - 7.0)
    }
}

/// All coordinator metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    pub jobs_run: AtomicU64,
    pub batched_members: AtomicU64,
    pub queue_rejections: AtomicU64,
    pub solve_latency: Histogram,
    pub queue_wait: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Serialize a snapshot to JSON.
    pub fn to_json(&self) -> Json {
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        ObjBuilder::new()
            .num("requests_submitted", c(&self.requests_submitted))
            .num("requests_completed", c(&self.requests_completed))
            .num("requests_failed", c(&self.requests_failed))
            .num("jobs_run", c(&self.jobs_run))
            .num("batched_members", c(&self.batched_members))
            .num("queue_rejections", c(&self.queue_rejections))
            .num("solve_latency_mean_s", self.solve_latency.mean())
            .num("solve_latency_p50_s", self.solve_latency.quantile(0.5))
            .num("solve_latency_p99_s", self.solve_latency.quantile(0.99))
            .num("queue_wait_mean_s", self.queue_wait.mean())
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_count_and_mean() {
        let h = Histogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-3 && p50 < 1e-2, "p50={p50}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn bucket_of_extremes() {
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(-1.0), 0);
        assert_eq!(Histogram::bucket_of(1e9), NBUCKETS - 1);
    }

    #[test]
    fn metrics_json_has_fields() {
        let m = Metrics::new();
        m.requests_submitted.store(5, Ordering::Relaxed);
        m.solve_latency.record(0.01);
        let j = m.to_json();
        assert_eq!(j.get("requests_submitted").unwrap().as_f64(), Some(5.0));
        assert!(j.get("solve_latency_mean_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
