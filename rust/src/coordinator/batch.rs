//! Batching policy: coalesce queued requests that share the same input
//! matrix into one multi-RHS [`SolveJob`].
//!
//! The serving analogue: requests against the same "model" (matrix) are
//! batched so the expensive shared work — column norms, walking the matrix
//! through cache — is paid once per batch instead of once per request.
//! Requests with different matrices, options, or backend hints never mix.

use std::collections::HashMap;

use crate::api::SolverKind;

use super::request::{SolveJob, SolveRequest};

/// Batching limits.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum members per job.
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32 }
    }
}

/// Group a drained set of requests into jobs.
///
/// Key = (matrix identity, backend hint, option fingerprint). Within a
/// key, members are chunked to `max_batch`. Order within a job follows
/// arrival order, and job emission order follows first-arrival of the key
/// (deterministic; tested).
pub fn coalesce(requests: Vec<SolveRequest>, policy: &BatchPolicy) -> Vec<SolveJob> {
    let mut order: Vec<(usize, SolverKind, u64)> = Vec::new();
    let mut groups: HashMap<(usize, SolverKind, u64), Vec<SolveRequest>> = HashMap::new();
    for r in requests {
        let key = (r.matrix_key(), r.backend, opts_fingerprint(&r));
        if !groups.contains_key(&key) {
            order.push(key);
        }
        groups.entry(key).or_default().push(r);
    }

    let mut jobs = Vec::new();
    for key in order {
        let members = groups.remove(&key).unwrap();
        let mut iter = members.into_iter().peekable();
        while iter.peek().is_some() {
            let chunk: Vec<SolveRequest> =
                iter.by_ref().take(policy.max_batch.max(1)).collect();
            let first = &chunk[0];
            jobs.push(SolveJob {
                x: first.x.clone(),
                opts: first.opts.clone(),
                backend: first.backend,
                members: chunk.iter().map(|r| (r.id, r.y.clone())).collect(),
                // Traced requests never reach the coalescer (the scheduler
                // partitions them into singleton jobs first), so a batch
                // job carries no trace.
                trace: None,
            });
        }
    }
    jobs
}

/// A stable fingerprint of the solve options that affect results —
/// requests only batch when these agree.
fn opts_fingerprint(r: &SolveRequest) -> u64 {
    let o = &r.opts;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(o.max_sweeps as u64);
    mix(o.tol.to_bits());
    mix(o.thr as u64);
    mix(o.threads as u64);
    mix(o.check_every as u64);
    mix(match o.order {
        crate::solver::ColumnOrder::Cyclic => 1,
        crate::solver::ColumnOrder::Shuffled => 2,
    });
    mix(o.seed);
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::solver::SolveOptions;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn mk(rng: &mut Rng) -> Arc<Mat> {
        Arc::new(Mat::randn(rng, 8, 4))
    }

    fn req(id: u64, x: &Arc<Mat>) -> SolveRequest {
        SolveRequest::new(id, x.clone(), vec![id as f32; 8])
    }

    #[test]
    fn same_matrix_coalesces() {
        let mut rng = Rng::seed(1);
        let x = mk(&mut rng);
        let jobs = coalesce(vec![req(1, &x), req(2, &x), req(3, &x)], &BatchPolicy::default());
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].len(), 3);
        assert_eq!(jobs[0].members.iter().map(|m| m.0).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn different_matrices_split() {
        let mut rng = Rng::seed(2);
        let x1 = mk(&mut rng);
        let x2 = mk(&mut rng);
        let jobs = coalesce(vec![req(1, &x1), req(2, &x2), req(3, &x1)], &BatchPolicy::default());
        assert_eq!(jobs.len(), 2);
        // First-arrival order: x1 job first, containing ids 1 and 3.
        assert_eq!(jobs[0].members.iter().map(|m| m.0).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(jobs[1].members[0].0, 2);
    }

    #[test]
    fn different_options_split() {
        let mut rng = Rng::seed(3);
        let x = mk(&mut rng);
        let mut r2 = req(2, &x);
        r2.opts = SolveOptions { tol: 1e-3, ..SolveOptions::default() };
        let jobs = coalesce(vec![req(1, &x), r2], &BatchPolicy::default());
        assert_eq!(jobs.len(), 2);
    }

    #[test]
    fn different_backends_split() {
        let mut rng = Rng::seed(4);
        let x = mk(&mut rng);
        let mut r2 = req(2, &x);
        r2.backend = crate::coordinator::Backend::Qr;
        let jobs = coalesce(vec![req(1, &x), r2], &BatchPolicy::default());
        assert_eq!(jobs.len(), 2);
    }

    #[test]
    fn max_batch_chunks() {
        let mut rng = Rng::seed(5);
        let x = mk(&mut rng);
        let reqs: Vec<_> = (0..10).map(|i| req(i, &x)).collect();
        let jobs = coalesce(reqs, &BatchPolicy { max_batch: 4 });
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].len(), 4);
        assert_eq!(jobs[1].len(), 4);
        assert_eq!(jobs[2].len(), 2);
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(coalesce(vec![], &BatchPolicy::default()).is_empty());
    }

    #[test]
    fn rhs_kept_per_member() {
        let mut rng = Rng::seed(6);
        let x = mk(&mut rng);
        let jobs = coalesce(vec![req(4, &x), req(9, &x)], &BatchPolicy::default());
        assert_eq!(jobs[0].members[0].1[0], 4.0);
        assert_eq!(jobs[0].members[1].1[0], 9.0);
    }
}
