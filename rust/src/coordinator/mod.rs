//! The L3 coordinator: a solve-request service in the serving-router mould
//! (vllm-project/router), but the "model" is a solver backend.
//!
//! Pieces:
//! * [`request`] — typed requests/responses; multi-RHS solve jobs.
//! * [`queue`]   — bounded MPMC job queue with backpressure (std-only).
//! * [`router`]  — backend selection policy: native BAK/BAKP/QR or a PJRT
//!   artifact bucket, chosen from problem shape + request hints.
//! * [`batch`]   — batching policy: coalesces requests that share the same
//!   input matrix into one multi-RHS job (amortises column norms and the
//!   matrix walk — the serving-batch analogue for solvers).
//! * [`metrics`] — counters + latency histograms, JSON-dumpable.
//! * [`service`] — the leader: worker pool, request lifecycle, shutdown.

pub mod batch;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod server;
pub mod service;

pub use crate::api::SolverKind;
pub use request::{Backend, SharedMatrix, SolveJob, SolveOutcome, SolveRequest};
pub use router::{route, RouteDecision};
pub use service::{Coordinator, CoordinatorConfig};
