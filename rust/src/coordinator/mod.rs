//! The L3 coordinator: a solve-request service in the serving-router mould
//! (vllm-project/router), but the "model" is a solver backend.
//!
//! Pieces:
//! * [`request`] — typed requests/responses; multi-RHS solve jobs.
//! * [`queue`]   — re-export of the crate-wide bounded MPMC queue
//!   ([`crate::parallel::queue`]).
//! * [`router`]  — backend selection policy: native BAK/BAKP/QR, the
//!   block-parallel variants when a request asks for threads, or a PJRT
//!   artifact bucket, chosen from problem shape + request hints.
//! * [`batch`]   — batching policy: coalesces requests that share the same
//!   input matrix into one multi-RHS job (amortises column norms and the
//!   matrix walk — the serving-batch analogue for solvers).
//! * [`metrics`] — counters + latency histograms + worker-pool gauges,
//!   JSON-dumpable and exportable as Prometheus text
//!   ([`metrics::Metrics::to_prometheus`]).
//! * [`service`] — the leader: scheduler + [`crate::parallel::Executor`]
//!   worker pool (panic isolation per job, graceful drain-on-shutdown),
//!   request lifecycle. Traced requests ([`SolveRequest::traced`]) run as
//!   singleton jobs and come back with a [`crate::obs::Telemetry`]: span
//!   timeline + convergence trajectory, retained in a bounded ring of
//!   recent traces ([`service::Coordinator::traces`]).

pub mod batch;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod server;
pub mod service;

pub use crate::api::SolverKind;
pub use request::{Backend, SharedMatrix, SolveJob, SolveOutcome, SolveRequest};
pub use router::{route, RouteDecision};
pub use service::{Coordinator, CoordinatorConfig};
