//! The coordinator service: leader + scheduler + worker pool.
//!
//! Lifecycle:
//!
//! ```text
//! client --submit()--> submit queue --scheduler (drain+coalesce)--> job
//!        <-Receiver--- worker pool  <----- executor injector <------+
//! ```
//!
//! * The **scheduler** thread drains the submit queue, coalesces requests
//!   sharing a matrix into multi-RHS jobs ([`super::batch`]), and feeds
//!   the [`crate::parallel::Executor`]'s bounded injector (backpressure
//!   propagates to submitters).
//! * The **executor**'s workers pull jobs, route them ([`super::router`]),
//!   and run the backend with panic isolation per job (a panicking solve
//!   is counted in `worker_panics` and its clients get a dropped-channel
//!   error; the worker survives). Batched jobs amortise shared work: QR
//!   factors the matrix once per job; the CD solvers compute column norms
//!   once per job. Worker count comes from
//!   [`CoordinatorConfig::workers`], whose default honours
//!   `PALLAS_THREADS` ([`crate::parallel::default_threads`]).
//! * Every request gets its own `mpsc` reply channel; [`Coordinator::submit`]
//!   returns the receiver.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::api::{
    report_from_coefficients, solver_for, PjrtSolver, Problem, Solver, SolverError, SolverKind,
};
use crate::baselines::qr;
use crate::linalg::Mat;
use crate::parallel::Executor;
use crate::runtime::Engine;
use crate::solver::{self, SolveReport};
use crate::util::log::{emit, emit_traced, Level};

use crate::obs::{ProbeHandle, RingProbe, Telemetry, TraceCtx, TraceRing};

use super::batch::{coalesce, BatchPolicy};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::{SharedMatrix, SolveJob, SolveOutcome, SolveRequest};
use super::router::route;

/// Points kept per traced solve's convergence trajectory (the probe
/// downsamples past this, never reallocates).
const TRACE_TRAJECTORY_CAP: usize = 256;

/// Completed traced solves retained for the server's `traces` command.
const TRACE_RING_CAP: usize = 64;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads executing jobs. The default honours the
    /// `PALLAS_THREADS` environment variable, then the machine's
    /// available parallelism ([`crate::parallel::default_threads`]).
    pub workers: usize,
    /// Submit-queue capacity (backpressure bound).
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Artifact directory; enables the PJRT backend when present & valid.
    pub artifact_dir: Option<PathBuf>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: crate::parallel::default_threads(),
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            artifact_dir: None,
        }
    }
}

struct Envelope {
    req: SolveRequest,
    reply: mpsc::Sender<SolveOutcome>,
    submitted: Instant,
}

struct JobEnvelope {
    job: SolveJob,
    replies: Vec<(mpsc::Sender<SolveOutcome>, Instant)>,
}

/// The running service. Dropping it shuts down cleanly.
pub struct Coordinator {
    submit_q: Arc<BoundedQueue<Envelope>>,
    metrics: Arc<Metrics>,
    traces: Arc<TraceRing>,
    engine: Option<Arc<Engine>>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    executor: Option<Arc<Executor<JobEnvelope>>>,
}

impl Coordinator {
    /// Start the service: spawns the scheduler and a
    /// `config.workers`-wide [`Executor`].
    pub fn start(config: CoordinatorConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let traces = Arc::new(TraceRing::new(TRACE_RING_CAP));
        let engine = config.artifact_dir.as_ref().and_then(|dir| match Engine::new(dir) {
            Ok(e) => Some(Arc::new(e)),
            Err(err) => {
                emit(Level::Warn, "coordinator", format_args!(
                    "PJRT engine unavailable ({err}); native backends only"));
                None
            }
        });

        let submit_q: Arc<BoundedQueue<Envelope>> =
            Arc::new(BoundedQueue::new(config.queue_capacity));

        // The worker pool: N workers pulling jobs from a bounded injector,
        // panic-isolated per job (a panicking solve drops its reply
        // senders — clients observe a typed Service error — and the
        // worker keeps serving).
        let executor = {
            let metrics = metrics.clone();
            let engine = engine.clone();
            let traces = traces.clone();
            Arc::new(Executor::start(
                "bak-worker",
                config.workers.max(1),
                config.queue_capacity,
                move |_worker, env: JobEnvelope| {
                    metrics
                        .job_queue_depth
                        .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                    run_job(env, engine.as_ref(), &metrics, &traces);
                },
            ))
        };
        metrics.attach_pool(executor.stats());

        // Scheduler: drain submit queue, coalesce, feed the executor.
        let scheduler = {
            let submit_q = submit_q.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            let policy = config.batch;
            std::thread::Builder::new()
                .name("bak-scheduler".into())
                .spawn(move || {
                    while let Some(first) = submit_q.pop() {
                        // Opportunistic coalescing window: whatever else is
                        // already queued right now.
                        let mut envs = vec![first];
                        envs.extend(submit_q.drain_now());
                        schedule_batch(envs, &policy, &executor, &metrics);
                    }
                })
                .expect("spawn scheduler")
        };

        Self {
            submit_q,
            metrics,
            traces,
            engine,
            scheduler: Some(scheduler),
            executor: Some(executor),
        }
    }

    /// Submit a request; returns the reply receiver. Blocks when the
    /// submit queue is full (backpressure); errors after shutdown.
    pub fn submit(
        &self,
        req: SolveRequest,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolverError> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests_submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.submit_q
            .push(Envelope { req, reply: tx, submitted: Instant::now() })
            .map_err(|_| SolverError::Service("coordinator is shut down".into()))?;
        Ok(rx)
    }

    /// Submit without blocking; Err(request) when the queue is full.
    pub fn try_submit(
        &self,
        req: SolveRequest,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolveRequest> {
        let (tx, rx) = mpsc::channel();
        match self.submit_q.try_push(Envelope { req, reply: tx, submitted: Instant::now() }) {
            Ok(()) => {
                self.metrics
                    .requests_submitted
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(rx)
            }
            Err(env) => {
                self.metrics
                    .queue_rejections
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(env.req)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn solve_blocking(&self, req: SolveRequest) -> SolveOutcome {
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| SolveOutcome {
                id: 0,
                report: Err(SolverError::Service("reply channel dropped".into())),
                backend: SolverKind::Auto,
                seconds: 0.0,
                batch_size: 0,
                telemetry: None,
            }),
            Err(e) => SolveOutcome {
                id: 0,
                report: Err(e),
                backend: SolverKind::Auto,
                seconds: 0.0,
                batch_size: 0,
                telemetry: None,
            },
        }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Ring of recently completed traced solves (oldest first in
    /// [`TraceRing::recent`]).
    pub fn traces(&self) -> &Arc<TraceRing> {
        &self.traces
    }

    /// The PJRT engine, when artifacts were loaded.
    pub fn engine(&self) -> Option<&Arc<Engine>> {
        self.engine.as_ref()
    }

    /// Graceful shutdown: stop intake, drain, join.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Stop intake, let the scheduler flush everything it has into the
        // executor, then drain the executor (pending jobs still run).
        self.submit_q.close();
        if let Some(s) = self.scheduler.take() {
            let _ = s.join();
        }
        if let Some(exec) = self.executor.take() {
            if let Ok(exec) = Arc::try_unwrap(exec).map_err(|_| ()) {
                exec.shutdown();
            }
            // A still-shared executor (scheduler clone already dropped by
            // the join above, so this is unreachable in practice) shuts
            // down via its Drop impl.
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn schedule_batch(
    envs: Vec<Envelope>,
    policy: &BatchPolicy,
    executor: &Executor<JobEnvelope>,
    metrics: &Metrics,
) {
    // Preserve reply channels through the coalescer by id.
    let mut replies: std::collections::HashMap<u64, (mpsc::Sender<SolveOutcome>, Instant)> =
        std::collections::HashMap::new();
    let mut reqs = Vec::with_capacity(envs.len());
    for env in envs {
        metrics.queue_wait.record(env.submitted.elapsed().as_secs_f64());
        if let Some(ctx) = env.req.trace.clone() {
            // Traced requests become singleton jobs — coalescing would
            // make the span timeline and trajectory describe a batch, not
            // the request. The queue wait is recorded retroactively: the
            // span began when the request was submitted.
            ctx.record_ns("queue_wait", ctx.ns_of(env.submitted), ctx.now_ns(), None);
            metrics.job_queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let job = SolveJob::single(env.req);
            let env = JobEnvelope { job, replies: vec![(env.reply, env.submitted)] };
            if executor.submit(env).is_err() {
                metrics.job_queue_depth.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                return; // shutting down
            }
            continue;
        }
        replies.insert(env.req.id, (env.reply, env.submitted));
        reqs.push(env.req);
    }
    for job in coalesce(reqs, policy) {
        let job_replies: Vec<_> = job
            .members
            .iter()
            .map(|(id, _)| replies.remove(id).expect("reply channel per member"))
            .collect();
        if job.len() > 1 {
            metrics
                .batched_members
                .fetch_add(job.len() as u64, std::sync::atomic::Ordering::Relaxed);
        }
        // Gauge up BEFORE the submit so a worker's pop-side decrement can
        // never observe the queue entry ahead of the increment.
        metrics.job_queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if executor.submit(JobEnvelope { job, replies: job_replies }).is_err() {
            metrics.job_queue_depth.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            return; // shutting down; remaining replies drop -> RecvError
        }
    }
}

fn run_job(
    env: JobEnvelope,
    engine: Option<&Arc<Engine>>,
    metrics: &Metrics,
    traces: &TraceRing,
) {
    let JobEnvelope { mut job, replies } = env;
    metrics.jobs_run.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // Traced job: mint a probe into the options so the solver loop feeds
    // the trajectory ring, and open per-stage spans around route / solve /
    // merge below. Untraced jobs skip all of it (probe stays disabled).
    let tracing: Option<(Arc<TraceCtx>, Arc<RingProbe>)> = job.trace.clone().map(|ctx| {
        let probe = RingProbe::new(TRACE_TRAJECTORY_CAP);
        job.opts.probe = ProbeHandle::new(probe.clone());
        (ctx, probe)
    });
    let route_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("route", None));
    let decision = route(
        job.backend,
        job.x.rows(),
        job.x.cols(),
        job.x.is_sparse(),
        job.x.is_streamed(),
        job.opts.threads,
        engine.map(|e| e.manifest()),
    );
    if let (Some((ctx, _)), Some(idx)) = (&tracing, route_span) {
        ctx.end(idx);
    }
    metrics.record_backend_job(decision.backend);
    let batch_size = job.len();
    let solve_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("solve", None));
    let trace_arg: Option<(&TraceCtx, usize)> = match (&tracing, solve_span) {
        (Some((ctx, _)), Some(idx)) => Some((ctx.as_ref(), idx)),
        _ => None,
    };
    let outcomes = execute_job(&job, decision.backend, engine, metrics, trace_arg);
    if let (Some((ctx, _)), Some(idx)) = (&tracing, solve_span) {
        ctx.end(idx);
    }

    // Merge stage: attribute latencies and stitch ids back on.
    let merge_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("merge", None));
    let mut merged = Vec::with_capacity(outcomes.len());
    for ((id, _), outcome) in job.members.iter().zip(outcomes) {
        let ok = outcome.report.is_ok();
        metrics.solve_latency.record(outcome.seconds);
        if ok {
            metrics.requests_completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        } else {
            metrics.requests_failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        merged.push(SolveOutcome { id: *id, batch_size, ..outcome });
    }
    if let (Some((ctx, _)), Some(idx)) = (&tracing, merge_span) {
        ctx.end(idx);
    }

    // Assemble the telemetry AFTER every span closed so the snapshot is
    // complete, keep a copy in the service-wide ring, and attach it to the
    // (singleton) traced outcome.
    let telemetry = tracing.map(|(ctx, probe)| {
        let tel = Telemetry {
            trace_id: ctx.id(),
            spans: ctx.spans(),
            trajectory: probe.snapshot(),
        };
        traces.push(tel.clone());
        tel
    });
    if let Some(t) = &telemetry {
        emit_traced(
            Level::Debug,
            "coordinator",
            Some(t.trace_id),
            format_args!(
                "traced solve on '{}': {} spans, {} trajectory points",
                decision.backend,
                t.spans.len(),
                t.trajectory.len()
            ),
        );
    }
    for (mut outcome, (reply, _submitted)) in merged.into_iter().zip(replies) {
        if let Some(t) = &telemetry {
            outcome.telemetry = Some(t.clone());
        }
        let _ = reply.send(outcome);
    }
}

/// Execute all members of a job on the routed backend, dispatching on the
/// matrix representation first: sparse jobs run natively on backends whose
/// `supports_sparse` capability is set; for every other backend the matrix
/// is densified once per job (logged + counted in `densified_jobs`) and
/// the dense path below takes over. Streamed (file-backed) jobs run the
/// chunk-pass solvers for the streaming trio and are never densified —
/// non-streaming backends return a typed error instead.
fn execute_job(
    job: &SolveJob,
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
    metrics: &Metrics,
    trace: Option<(&TraceCtx, usize)>,
) -> Vec<SolveOutcome> {
    match &job.x {
        SharedMatrix::Dense(x) => {
            // The batcher shares one matrix across the whole job: scan it
            // once here, before any factorization work, and only check
            // each member's (cheap) y side below.
            if let Err(e) = Problem::validate_matrix(x) {
                return per_member(job, backend, |_| Err(e.clone()));
            }
            execute_dense_job(job, x, backend, engine)
        }
        SharedMatrix::SparseCsc(s) => {
            if let Err(e) = Problem::validate_sparse_matrix(s) {
                return per_member(job, backend, |_| Err(e.clone()));
            }
            let native = backend.capabilities().is_some_and(|c| c.supports_sparse);
            if native {
                match backend {
                    // Amortise shared per-matrix work across the batch,
                    // mirroring the dense paths below: BAK computes the
                    // O(nnz) column norms once per job...
                    SolverKind::Bak => {
                        let cninv = crate::sparse::solve::colnorms_inv_csc(s);
                        per_member(job, backend, |y| {
                            Problem::prevalidated_sparse(s, y)?;
                            let mut a = vec![0.0f32; s.cols()];
                            let mut e = y.to_vec();
                            Ok(crate::sparse::solve::solve_bak_csc_warm(
                                s, &cninv, &mut a, &mut e, y, &job.opts,
                            ))
                        })
                    }
                    // ...and Kaczmarz transposes CSC->CSR once per job.
                    SolverKind::Kaczmarz => {
                        let csr = s.to_csr();
                        per_member(job, backend, |y| {
                            Problem::prevalidated_sparse(s, y)?;
                            Ok(crate::sparse::solve::solve_kaczmarz_csr(&csr, y, &job.opts))
                        })
                    }
                    _ => match solver_for(backend) {
                        Some(solver) => per_member(job, backend, |y| {
                            let p = Problem::prevalidated_sparse(s, y)?;
                            solver.solve(&p, &job.opts)
                        }),
                        None => per_member(job, backend, |_| {
                            Err(SolverError::Unavailable {
                                backend: backend.to_string(),
                                reason: "routing pseudo-kind; not directly executable".into(),
                            })
                        }),
                    },
                }
            } else {
                metrics.densified_jobs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                emit(
                    Level::Warn,
                    "coordinator",
                    format_args!(
                        "backend '{backend}' has no native sparse path; densifying {}x{} \
                         (nnz={}) for a {}-member job",
                        s.rows(),
                        s.cols(),
                        s.nnz(),
                        job.len()
                    ),
                );
                let densify_span = trace.map(|(ctx, parent)| ctx.begin("densify", Some(parent)));
                let dense = s.to_dense();
                if let (Some((ctx, _)), Some(idx)) = (trace, densify_span) {
                    ctx.end(idx);
                }
                execute_dense_job(job, &dense, backend, engine)
            }
        }
        SharedMatrix::Streamed(s) => {
            // File-backed jobs never materialise X in RAM: the streaming
            // trio consumes sequential chunk passes (recording the
            // read/stall counters), and every other backend returns its
            // typed refusal from the backends layer instead of OOMing.
            let record = |st: &crate::stream::StreamStatsSnapshot| {
                use std::sync::atomic::Ordering::Relaxed;
                metrics.stream_chunks_read.fetch_add(st.chunks_read, Relaxed);
                metrics.stream_bytes_read.fetch_add(st.bytes_read, Relaxed);
                metrics.stream_buffer_stalls.fetch_add(st.buffer_stalls, Relaxed);
            };
            // Streamed solves interleave disk reads with compute, so the
            // `stream_io` child span covers the whole chunk-pass solve —
            // it marks the phase whose wall time includes IO, not an
            // isolated IO measurement (the stall *count* is in metrics).
            let io_spanned = |f: &mut dyn FnMut() -> Result<SolveReport, SolverError>| {
                let io_span = trace.map(|(ctx, parent)| ctx.begin("stream_io", Some(parent)));
                let r = f();
                if let (Some((ctx, _)), Some(idx)) = (trace, io_span) {
                    ctx.end(idx);
                }
                r
            };
            match backend {
                SolverKind::Bak => per_member(job, backend, |y| {
                    io_spanned(&mut || {
                        let r = crate::stream::solve_bak_stream(s, y, &job.opts)?;
                        record(&r.stats);
                        Ok(r.report)
                    })
                }),
                SolverKind::Kaczmarz => per_member(job, backend, |y| {
                    io_spanned(&mut || {
                        let r = crate::stream::solve_kaczmarz_stream(s, y, &job.opts)?;
                        record(&r.stats);
                        Ok(r.report)
                    })
                }),
                SolverKind::BakMulti => {
                    // Every valid member in ONE set of chunk passes
                    // (mirrors the dense multi path); invalid members get
                    // their own error without demoting the batch.
                    let t0 = Instant::now();
                    let checks: Vec<Result<(), SolverError>> = job
                        .members
                        .iter()
                        .map(|(_, y)| Problem::new_streamed(s, y).map(|_| ()))
                        .collect();
                    let ys: Vec<Vec<f32>> = job
                        .members
                        .iter()
                        .zip(&checks)
                        .filter(|(_, c)| c.is_ok())
                        .map(|((_, y), _)| y.clone())
                        .collect();
                    let io_span =
                        trace.map(|(ctx, parent)| ctx.begin("stream_io", Some(parent)));
                    let multi_res = crate::stream::solve_bak_multi_stream(s, &ys, &job.opts);
                    if let (Some((ctx, _)), Some(idx)) = (trace, io_span) {
                        ctx.end(idx);
                    }
                    match multi_res {
                        Ok(multi) => {
                            record(&multi.stats);
                            let mut reports = multi.reports.into_iter();
                            let secs =
                                t0.elapsed().as_secs_f64() / job.len().max(1) as f64;
                            checks
                                .into_iter()
                                .map(|c| SolveOutcome {
                                    id: 0,
                                    report: c.map(|()| {
                                        reports
                                            .next()
                                            .expect("one report per valid member")
                                    }),
                                    backend,
                                    seconds: secs,
                                    batch_size: 0,
                                    telemetry: None,
                                })
                                .collect()
                        }
                        Err(e) => per_member(job, backend, |_| Err(e.clone())),
                    }
                }
                _ => match solver_for(backend) {
                    Some(solver) => per_member(job, backend, |y| {
                        let p = Problem::new_streamed(s, y)?;
                        solver.solve(&p, &job.opts)
                    }),
                    None => per_member(job, backend, |_| {
                        Err(SolverError::Unavailable {
                            backend: backend.to_string(),
                            reason: "routing pseudo-kind; not directly executable".into(),
                        })
                    }),
                },
            }
        }
    }
}

/// The dense execution paths, amortising shared work across the batch
/// where the backend allows it (QR factors once per job, BAK shares column
/// norms, BAK-multi walks the matrix once for every right-hand side); all
/// other registered kinds run member-by-member through the [`crate::api`]
/// registry.
fn execute_dense_job(
    job: &SolveJob,
    x: &Mat,
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
) -> Vec<SolveOutcome> {
    match backend {
        SolverKind::Qr => {
            // Factor ONCE for the whole batch (tall only; wide falls back
            // to per-member lstsq which handles min-norm internally).
            if x.rows() >= x.cols() {
                let t0 = Instant::now();
                let (f, taus) = qr::householder_qr(x);
                let factor_s = t0.elapsed().as_secs_f64() / job.len() as f64;
                job.members
                    .iter()
                    .map(|(_, y)| {
                        let t1 = Instant::now();
                        let report = qr_member_solve(x, &f, &taus, y);
                        SolveOutcome {
                            id: 0,
                            report,
                            backend,
                            seconds: factor_s + t1.elapsed().as_secs_f64(),
                            batch_size: 0,
                            telemetry: None,
                        }
                    })
                    .collect()
            } else {
                per_member(job, backend, |y| {
                    Problem::prevalidated(x, y)?;
                    let a = qr::lstsq_qr(x, y)?;
                    Ok(report_from_coefficients(x, y, a))
                })
            }
        }
        SolverKind::Bak => {
            let cninv = solver::colnorms_inv(x);
            per_member(job, backend, |y| {
                Problem::prevalidated(x, y)?;
                let mut a = vec![0.0f32; x.cols()];
                let mut e = y.to_vec();
                Ok(solver::bak::solve_bak_warm(x, &cninv, &mut a, &mut e, y, &job.opts))
            })
        }
        SolverKind::BakMulti => {
            // Every valid member in ONE matrix walk (chunked across
            // threads when the request asks for them — the column-norm
            // precompute is still shared); invalid members get their own
            // error without demoting the rest of the batch.
            let t0 = Instant::now();
            let checks: Vec<Result<(), SolverError>> = job
                .members
                .iter()
                .map(|(_, y)| Problem::prevalidated(x, y).map(|_| ()))
                .collect();
            let ys: Vec<Vec<f32>> = job
                .members
                .iter()
                .zip(&checks)
                .filter(|(_, c)| c.is_ok())
                .map(|((_, y), _)| y.clone())
                .collect();
            let reports = if job.opts.threads > 1 {
                crate::parallel::solve_bak_multi_par(x, &ys, &job.opts)
            } else {
                solver::solve_bak_multi(x, &ys, &job.opts)
            };
            let mut reports = reports.into_iter();
            let secs = t0.elapsed().as_secs_f64() / job.len().max(1) as f64;
            checks
                .into_iter()
                .map(|c| SolveOutcome {
                    id: 0,
                    report: c
                        .map(|()| reports.next().expect("one report per valid member")),
                    backend,
                    seconds: secs,
                    batch_size: 0,
                    telemetry: None,
                })
                .collect()
        }
        SolverKind::Pjrt => {
            // Reuse the api adapter: detached -> typed Unavailable, with
            // an engine -> artifact execution. One error contract.
            let pjrt = match engine {
                Some(eng) => PjrtSolver::with_engine(eng.clone()),
                None => PjrtSolver::detached(),
            };
            per_member(job, backend, |y| {
                let p = Problem::prevalidated(x, y)?;
                pjrt.solve(&p, &job.opts)
            })
        }
        SolverKind::Auto => unreachable!("router always resolves Auto"),
        kind => match solver_for(kind) {
            // Everything else (bakp, kaczmarz, gauss_southwell, cholesky,
            // gauss, cgls) dispatches through the registry.
            Some(s) => per_member(job, kind, |y| {
                let p = Problem::prevalidated(x, y)?;
                s.solve(&p, &job.opts)
            }),
            None => per_member(job, kind, |_| {
                Err(SolverError::Unavailable {
                    backend: kind.to_string(),
                    reason: "routing pseudo-kind; not directly executable".into(),
                })
            }),
        },
    }
}

fn per_member(
    job: &SolveJob,
    backend: SolverKind,
    mut f: impl FnMut(&[f32]) -> Result<SolveReport, SolverError>,
) -> Vec<SolveOutcome> {
    job.members
        .iter()
        .map(|(_, y)| {
            let t0 = Instant::now();
            let report = f(y);
            SolveOutcome {
                id: 0,
                report,
                backend,
                seconds: t0.elapsed().as_secs_f64(),
                batch_size: 0,
                telemetry: None,
            }
        })
        .collect()
}

fn qr_member_solve(
    x: &Mat,
    f: &Mat,
    taus: &[f32],
    y: &[f32],
) -> Result<SolveReport, SolverError> {
    Problem::prevalidated(x, y)?;
    let qty = qr::apply_qt(f, taus, y);
    let a = qr::solve_upper_triangular(f, &qty)?;
    Ok(report_from_coefficients(x, y, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Arc<Mat>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (Arc::new(x), y, a)
    }

    #[test]
    fn solve_roundtrip_native_bak() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(400, 600, 30);
        let mut req = SolveRequest::new(1, x, y);
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        let rep = out.report.expect("solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        assert_eq!(out.backend, SolverKind::Bak);
        coord.shutdown();
    }

    #[test]
    fn auto_routes_square_to_qr() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(401, 50, 50);
        let out = coord.solve_blocking(SolveRequest::new(2, x, y));
        assert_eq!(out.backend, SolverKind::Qr);
        let rep = out.report.unwrap();
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-2);
        coord.shutdown();
    }

    #[test]
    fn batched_same_matrix_requests_all_answered() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        });
        let (x, _, _) = planted(402, 300, 20);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let mut rng = Rng::seed(500 + i);
            let a: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
            let y = x.matvec(&a);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = SolverKind::Qr;
            rxs.push((i, a, coord.submit(req).unwrap()));
        }
        for (i, a_true, rx) in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, i);
            let rep = out.report.unwrap();
            assert!(
                crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3,
                "member {i}"
            );
        }
        coord.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(403, 20, 5);
        coord.shutdown();
        // Start a fresh one to prove restartability, then check closed
        // submit path via a second coordinator's lifecycle.
        let coord2 = Coordinator::start(CoordinatorConfig::default());
        let out = coord2.solve_blocking(SolveRequest::new(9, x, y));
        assert!(out.report.is_ok());
    }

    #[test]
    fn metrics_accumulate() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(404, 100, 10);
        let _ = coord.solve_blocking(SolveRequest::new(1, x.clone(), y.clone()));
        let _ = coord.solve_blocking(SolveRequest::new(2, x, y));
        let m = coord.metrics();
        assert_eq!(m.requests_submitted.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(m.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert!(m.solve_latency.count() >= 2);
        coord.shutdown();
    }

    #[test]
    fn explicit_bakp_backend() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(405, 500, 40);
        let mut req = SolveRequest::new(3, x, y);
        req.backend = SolverKind::Bakp;
        req.opts = solver::SolveOptions::accurate();
        req.opts.thr = 8;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Bakp);
        let rep = out.report.unwrap();
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        coord.shutdown();
    }

    #[test]
    fn pjrt_without_engine_fails_cleanly() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(406, 100, 10);
        let mut req = SolveRequest::new(4, x, y);
        req.backend = SolverKind::Pjrt;
        let out = coord.solve_blocking(req);
        // Router falls back to Bakp when no engine manifest exists.
        assert_eq!(out.backend, SolverKind::Bakp);
        assert!(out.report.is_ok());
        coord.shutdown();
    }

    fn planted_sparse(
        seed: u64,
        obs: usize,
        vars: usize,
        density: f64,
    ) -> (Arc<crate::sparse::CscMat>, Vec<f32>, Vec<f32>) {
        let w = crate::bench::workload::SparseWorkload::uniform(
            crate::bench::workload::WorkloadSpec::new(obs, vars, seed),
            density,
        );
        (Arc::new(w.x), w.y, w.a_true)
    }

    #[test]
    fn sparse_auto_runs_natively_without_densification() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_sparse(407, 300, 24, 0.1);
        let mut req = SolveRequest::new_sparse(1, x, y);
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        // Auto + sparse routes to a sparse-native solver...
        assert!(matches!(out.backend, SolverKind::Bak | SolverKind::Bakp));
        let rep = out.report.expect("sparse solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        // ...so nothing was densified, and the backend job was counted.
        let m = coord.metrics();
        assert_eq!(m.densified_jobs.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(m.backend_jobs(out.backend), 1);
        coord.shutdown();
    }

    #[test]
    fn sparse_request_on_dense_only_backend_densifies_and_counts() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_sparse(408, 120, 16, 0.15);
        let mut req = SolveRequest::new_sparse(2, x, y);
        req.backend = SolverKind::Qr;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Qr);
        let rep = out.report.expect("densified qr solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        let m = coord.metrics();
        assert_eq!(m.densified_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(m.backend_jobs(SolverKind::Qr), 1);
        coord.shutdown();
    }

    #[test]
    fn sparse_requests_batch_and_all_answer() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        });
        let (x, _, _) = planted_sparse(409, 200, 12, 0.2);
        let mut rng = Rng::seed(410);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let a: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let y = x.matvec(&a);
            let mut req = SolveRequest::new_sparse(i, x.clone(), y);
            req.backend = SolverKind::Cgls;
            req.opts = solver::SolveOptions::accurate();
            rxs.push((i, a, coord.submit(req).unwrap()));
        }
        for (i, a_true, rx) in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, i);
            let rep = out.report.expect("sparse cgls ok");
            assert!(
                crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-2,
                "member {i}"
            );
        }
        assert_eq!(
            coord.metrics().densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        coord.shutdown();
    }

    #[test]
    fn queue_depth_returns_to_zero_when_drained() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(411, 80, 8);
        let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
        assert_eq!(
            coord.metrics().job_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        coord.shutdown();
    }

    #[test]
    fn auto_with_threads_routes_to_bak_par() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(412, 4000, 16);
        let mut req = SolveRequest::new(1, x, y);
        req.opts = solver::SolveOptions::accurate();
        req.opts.threads = 4;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::BakPar);
        let rep = out.report.expect("threaded solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        coord.shutdown();
    }

    #[test]
    fn explicit_kaczmarz_par_backend_over_service() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(413, 480, 20);
        let mut req = SolveRequest::new(2, x, y);
        req.backend = SolverKind::KaczmarzPar;
        req.opts = solver::SolveOptions::builder()
            .max_sweeps(2000)
            .tol(1e-4)
            .threads(2)
            .build();
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::KaczmarzPar);
        let rep = out.report.expect("kaczmarz_par ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 0.05);
        coord.shutdown();
    }

    #[test]
    fn multi_member_sparse_job_densifies_once() {
        // The satellite contract: one warning/count per JOB, not per
        // member. Drive execute_job directly so the batch composition is
        // deterministic.
        let (x, _, _) = planted_sparse(414, 80, 10, 0.2);
        let mut rng = Rng::seed(415);
        let members: Vec<(u64, Vec<f32>)> = (0..5u64)
            .map(|i| {
                let a: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
                (i, x.matvec(&a))
            })
            .collect();
        let job = super::super::request::SolveJob {
            x: super::super::request::SharedMatrix::SparseCsc(x),
            members,
            opts: solver::SolveOptions::default(),
            backend: SolverKind::Qr,
            trace: None,
        };
        let metrics = Metrics::new();
        let outcomes = execute_job(&job, SolverKind::Qr, None, &metrics, None);
        assert_eq!(outcomes.len(), 5);
        assert!(outcomes.iter().all(|o| o.report.is_ok()));
        assert_eq!(
            metrics.densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "densification counted once for the whole job"
        );
    }

    fn planted_streamed(
        seed: u64,
        obs: usize,
        vars: usize,
        chunk: usize,
        tag: &str,
    ) -> (Arc<crate::stream::StreamedMatrix>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        let path = crate::stream::temp_chunk_path(tag);
        crate::stream::write_chunked_dense(&x, chunk, &path).expect("write chunked");
        let s = crate::stream::StreamedMatrix::open(&path).expect("open chunked");
        (Arc::new(s), y, a)
    }

    #[test]
    fn streamed_auto_routes_to_bak_and_counts_stream_metrics() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_streamed(420, 600, 30, 7, "svc_auto");
        let path = x.path().to_path_buf();
        let mut req = SolveRequest::new_streamed(1, x, y);
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Bak);
        let rep = out.report.expect("streamed solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        let m = coord.metrics();
        use std::sync::atomic::Ordering::Relaxed;
        assert!(m.stream_chunks_read.load(Relaxed) > 0);
        assert!(m.stream_bytes_read.load(Relaxed) > 0);
        assert_eq!(m.densified_jobs.load(Relaxed), 0);
        coord.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_job_on_non_streaming_backend_gets_typed_error() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted_streamed(421, 120, 10, 4, "svc_refuse");
        let path = x.path().to_path_buf();
        let mut req = SolveRequest::new_streamed(2, x, y);
        req.backend = SolverKind::Qr;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Qr, "hint honoured through routing");
        match out.report {
            Err(SolverError::Unavailable { backend, .. }) => assert_eq!(backend, "qr"),
            other => panic!("expected typed Unavailable, got {other:?}"),
        }
        assert_eq!(
            coord.metrics().densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "streamed jobs are never densified"
        );
        coord.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_multi_batch_all_answered_in_one_walk() {
        let (x, _, _) = planted_streamed(422, 200, 12, 5, "svc_multi");
        let path = x.path().to_path_buf();
        let mut rng = Rng::seed(423);
        let members: Vec<(u64, Vec<f32>)> = (0..4u64)
            .map(|i| {
                let a: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
                let y = x.to_mat().unwrap().matvec(&a);
                (i, y)
            })
            .collect();
        let job = super::super::request::SolveJob {
            x: super::super::request::SharedMatrix::Streamed(x),
            members,
            opts: solver::SolveOptions::accurate(),
            backend: SolverKind::BakMulti,
            trace: None,
        };
        let metrics = Metrics::new();
        let outcomes = execute_job(&job, SolverKind::BakMulti, None, &metrics, None);
        assert_eq!(outcomes.len(), 4);
        assert!(outcomes.iter().all(|o| o.report.is_ok()));
        assert!(
            metrics.stream_chunks_read.load(std::sync::atomic::Ordering::Relaxed) > 0
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn traced_request_returns_telemetry_and_fills_ring() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(430, 300, 20);
        let mut req = SolveRequest::new(11, x, y).traced();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::builder().max_sweeps(20).tol(0.0).build();
        let out = coord.solve_blocking(req);
        let rep = out.report.expect("traced solve ok");
        let tel = out.telemetry.expect("telemetry present on traced outcome");
        assert!(tel.trace_id > 0);
        // The trajectory mirrors the solver's residual history.
        assert!(!tel.trajectory.is_empty());
        assert_eq!(tel.trajectory.len(), rep.history.len().min(256));
        for w in tel.trajectory.windows(2) {
            assert!(w[0].sweep < w[1].sweep, "sweeps strictly increase");
        }
        // Spans: queue_wait + route + solve + merge at minimum, all closed.
        let names: Vec<&str> = tel.spans.iter().map(|s| s.name).collect();
        for stage in ["queue_wait", "route", "solve", "merge"] {
            assert!(names.contains(&stage), "{stage} span missing: {names:?}");
        }
        for s in &tel.spans {
            assert!(s.end_ns >= s.start_ns, "span {} never closed", s.name);
        }
        // The completed trace is retained in the service ring.
        let recent = coord.traces().recent(8);
        assert!(recent.iter().any(|t| t.trace_id == tel.trace_id));
        coord.shutdown();
    }

    #[test]
    fn untraced_request_has_no_telemetry() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(431, 60, 8);
        let out = coord.solve_blocking(SolveRequest::new(12, x, y));
        assert!(out.report.is_ok());
        assert!(out.telemetry.is_none());
        assert!(coord.traces().is_empty());
        coord.shutdown();
    }

    #[test]
    fn pool_gauges_flow_through_service_metrics() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 3,
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(416, 100, 10);
        for i in 0..4u64 {
            let _ = coord.solve_blocking(SolveRequest::new(i, x.clone(), y.clone()));
        }
        let j = coord.metrics().to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("jobs_inflight").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("worker_panics").unwrap().as_f64(), Some(0.0));
        let per_worker = j.get("worker_jobs").unwrap().items();
        assert_eq!(per_worker.len(), 3);
        let total: f64 = per_worker.iter().filter_map(|v| v.as_f64()).sum();
        assert!(total >= 4.0, "every job counted against a worker");
        coord.shutdown();
    }
}
